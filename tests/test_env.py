"""Environment invariants from the assignment."""

import os


def test_tests_see_one_device():
    """Only the dry-run sets --xla_force_host_platform_device_count; the
    test/bench processes must see the real single CPU device."""
    if "xla_force_host_platform_device_count" in os.environ.get("XLA_FLAGS", ""):
        import pytest
        pytest.skip("caller explicitly forced a device count")
    import jax
    assert jax.device_count() == 1


def test_mesh_module_import_touches_no_devices():
    """mesh.py must define meshes as functions, not module constants."""
    import importlib
    import sys
    for mod in ("repro.launch.mesh",):
        sys.modules.pop(mod, None)
        m = importlib.import_module(mod)
        consts = [k for k, v in vars(m).items()
                  if not k.startswith("_") and "Mesh" in type(v).__name__]
        assert not consts, f"module-level mesh constants found: {consts}"
