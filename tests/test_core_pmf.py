"""Unit + property tests for the PMF algebra (Eqs. 5.1-5.7)."""

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal install: keep unit tests, skip property tests
    from conftest import given, settings, st  # noqa: F401

from repro.core.pmf import (PMF, DropMode, chance_of_success, convolve_pct,
                            queue_pcts)


def _rand_pmf(rng, n=None, offset=None):
    n = n or int(rng.integers(1, 40))
    v = rng.random(n) + 1e-3
    return PMF(v / v.sum(), offset=int(offset if offset is not None
                                       else rng.integers(0, 30)))


# ---------------------------------------------------------------------------
# basics
# ---------------------------------------------------------------------------

def test_impulse_stats():
    p = PMF.impulse(7)
    assert p.mean() == 7 and p.std() == 0 and p.mass == 1.0
    assert p.success_before(7) == 1.0 and p.success_before(6) == 0.0


def test_from_normal_moments():
    p = PMF.from_normal(100, 7)
    assert abs(p.mean() - 100) < 0.5
    assert abs(p.std() - 7) < 0.5
    assert abs(p.mass - 1.0) < 1e-9


def test_negative_values_rejected():
    with pytest.raises(ValueError):
        PMF(np.array([0.5, -0.5]))


def test_scale_speed():
    p = PMF.from_normal(100, 5)
    q = p.scale(0.5)  # 2x faster machine
    assert abs(q.mean() - 50) < 1.0


def test_skewness_signs():
    assert PMF(np.array([0.7, 0.2, 0.1])).skewness() > 0
    assert PMF(np.array([0.1, 0.2, 0.7])).skewness() < 0
    assert abs(PMF(np.array([0.2, 0.6, 0.2])).skewness()) < 1e-9


# ---------------------------------------------------------------------------
# convolution forms (Eqs. 5.2-5.5)
# ---------------------------------------------------------------------------

def test_no_drop_is_plain_convolution():
    rng = np.random.default_rng(0)
    e, c = _rand_pmf(rng), _rand_pmf(rng)
    out = convolve_pct(e, c, deadline=None, mode=DropMode.NO_DROP)
    assert abs(out.mean() - (e.mean() + c.mean())) < 1e-9
    assert abs(out.mass - 1.0) < 1e-9


def test_pend_drop_mass_conserved_and_split():
    rng = np.random.default_rng(1)
    for _ in range(20):
        e, c = _rand_pmf(rng), _rand_pmf(rng)
        dl = int(c.mean() + e.mean())
        out = convolve_pct(e, c, deadline=dl, mode=DropMode.PEND_DROP)
        assert abs(out.mass - 1.0) < 1e-9
        # late prev mass passes through untouched
        late = sum(c.values[max(0, dl - c.offset):])
        # all mass at/after dl in `out` >= pass-through mass
        tail = sum(out.values[max(0, dl - out.offset):]) if out.support_end >= dl else 0
        assert tail >= late - 1e-9


def test_evict_drop_support_bounded():
    rng = np.random.default_rng(2)
    for _ in range(20):
        e, c = _rand_pmf(rng), _rand_pmf(rng)
        dl = int(c.offset + e.offset + 3)
        out = convolve_pct(e, c, deadline=dl, mode=DropMode.EVICT_DROP)
        assert abs(out.mass - 1.0) < 1e-9
        # the machine is guaranteed free of this task by max(dl, prev frees)
        assert out.support_end <= max(dl, c.support_end)


def test_chance_matches_materialized_convolution():
    rng = np.random.default_rng(3)
    for _ in range(30):
        e, c = _rand_pmf(rng), _rand_pmf(rng)
        dl = int(e.mean() + c.mean() + rng.integers(-5, 10))
        # no-drop: memoized == full convolution CDF
        p_memo = chance_of_success(e, c, dl, droppable_prev=False)
        p_full = convolve_pct(e, c, None, DropMode.NO_DROP).success_before(dl)
        assert abs(p_memo - p_full) < 1e-9


def test_chance_pend_drop_excludes_late_starts():
    # prev frees at exactly the deadline -> task i is dropped, chance 0
    e = PMF.impulse(1)          # exec takes 1
    c = PMF.impulse(10)         # prev frees at 10
    assert chance_of_success(e, c, 10, droppable_prev=True) == 0.0
    assert chance_of_success(e, c, 11, droppable_prev=True) == 1.0


def test_queue_pcts_monotone_means():
    rng = np.random.default_rng(4)
    pets = [_rand_pmf(rng) for _ in range(4)]
    pcts = queue_pcts(pets, [10**6] * 4, mode=DropMode.NO_DROP)
    means = [p.mean() for p in pcts]
    assert all(b > a for a, b in zip(means, means[1:]))


# ---------------------------------------------------------------------------
# compaction (Fig. 5.7)
# ---------------------------------------------------------------------------

def test_compaction_preserves_mass_and_mean():
    p = PMF.from_normal(120, 9)
    q = p.compact(4)
    assert abs(q.mass - p.mass) < 1e-12
    assert abs(q.mean() - p.mean()) < 4.0
    assert len([v for v in q.values if v > 0]) <= int(np.ceil(len(p.values) / 4)) + 1


def test_compaction_range_clamps():
    p = PMF.from_normal(50, 3)
    q = p.compact(2, lo=48, hi=52)
    assert q.offset >= 48 and q.support_end <= 52 + 2
    assert abs(q.mass - 1.0) < 1e-12


# ---------------------------------------------------------------------------
# property tests
# ---------------------------------------------------------------------------

@settings(max_examples=60, deadline=None)
@given(st.integers(1, 30), st.integers(1, 30), st.integers(0, 60),
       st.integers(0, 1000))
def test_prop_mass_conservation(n1, n2, dl_off, seed):
    rng = np.random.default_rng(seed)
    e, c = _rand_pmf(rng, n1), _rand_pmf(rng, n2)
    dl = e.offset + c.offset + dl_off
    for mode in DropMode:
        out = convolve_pct(e, c, dl, mode=mode)
        assert abs(out.mass - 1.0) < 1e-9, mode


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 25), st.integers(1, 25), st.integers(0, 1000))
def test_prop_chance_bounds_and_monotonicity(n1, n2, seed):
    rng = np.random.default_rng(seed)
    e, c = _rand_pmf(rng, n1), _rand_pmf(rng, n2)
    lo = e.offset + c.offset
    hi = e.support_end + c.support_end
    prev = 0.0
    for dl in range(lo - 1, hi + 2, max(1, (hi - lo) // 8)):
        p = chance_of_success(e, c, dl, droppable_prev=False)
        assert -1e-12 <= p <= 1.0 + 1e-12
        assert p >= prev - 1e-12       # CDF is monotone in the deadline
        prev = p
    # past the joint support the chance is certain
    assert chance_of_success(e, c, hi + 1, droppable_prev=False) > 1 - 1e-9


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.integers(0, 1000))
def test_prop_compaction_mass(bucket, seed):
    rng = np.random.default_rng(seed)
    p = _rand_pmf(rng, int(rng.integers(5, 120)))
    q = p.compact(bucket)
    assert abs(q.mass - p.mass) < 1e-12
    assert abs(q.mean() - p.mean()) <= bucket
