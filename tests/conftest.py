"""Shared test plumbing.

When ``hypothesis`` is unavailable (minimal installs; it is declared under
the ``test`` extra in pyproject.toml) the property tests degrade to skips
instead of breaking collection for the whole module: ``given`` swaps the
test body for a zero-cost skip stub and ``st``/``settings`` become inert.
"""

import pytest


def given(*_args, **_kwargs):
    def deco(fn):
        def stub(*_a, **_k):
            pytest.skip("hypothesis not installed")
        stub.__name__ = fn.__name__
        stub.__doc__ = fn.__doc__
        return stub
    return deco


def settings(*_args, **_kwargs):
    return lambda fn: fn


class _InertStrategies:
    def __getattr__(self, name):
        return lambda *a, **k: None


st = _InertStrategies()
