"""Paged KV prefix cache: pool/trie/facade invariants + the simulator's
analytical reuse model.  No JAX — this subsystem must stay importable and
testable on the pure-numpy simulation path."""

import numpy as np
import pytest

from repro.core.merging import MergeLevel, common_prefix_len
from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.tasks import Machine, PETMatrix, Task
from repro.serving.kvcache import BlockPool, PrefixIndex, PrefixKVCache


# ---------------------------------------------------------------------------
# block pool
# ---------------------------------------------------------------------------

class TestBlockPool:
    def test_alloc_free_roundtrip(self):
        pool = BlockPool(4, 16)
        blk = pool.alloc(payload="kv")
        assert pool.n_used == 1 and blk.payload == "kv"
        pool.free(blk)
        assert pool.n_used == 0 and blk.payload is None

    def test_never_freed_while_referenced(self):
        pool = BlockPool(2, 16)
        blk = pool.alloc()
        pool.incref(blk)
        with pytest.raises(RuntimeError, match="referenced"):
            pool.free(blk)
        pool.decref(blk)
        pool.free(blk)          # now legal

    def test_double_free_and_stray_refs_rejected(self):
        pool = BlockPool(2, 16)
        blk = pool.alloc()
        pool.free(blk)
        with pytest.raises(RuntimeError, match="double free"):
            pool.free(blk)
        with pytest.raises(RuntimeError):
            pool.incref(blk)
        blk2 = pool.alloc()
        with pytest.raises(RuntimeError):
            pool.decref(blk2)

    def test_exhaustion_returns_none(self):
        pool = BlockPool(2, 16)
        assert pool.alloc() is not None
        assert pool.alloc() is not None
        assert pool.alloc() is None


# ---------------------------------------------------------------------------
# prefix trie
# ---------------------------------------------------------------------------

class TestPrefixIndex:
    def test_block_granular_match(self):
        idx = PrefixIndex(4)
        pool = BlockPool(8, 4)
        toks = tuple(range(10))           # 2 whole blocks + 2-token tail
        node = idx.root
        for span in idx._spans(toks):
            node = idx.extend(node, span, pool.alloc())
        assert idx.match_len(toks) == 8   # tail fragment never indexed
        assert idx.match_len(toks[:7]) == 4
        assert idx.match_len((99,) + toks[1:]) == 0
        assert idx.match_len(toks, max_tokens=7) == 4

    def test_remove_leaf_only(self):
        idx = PrefixIndex(2)
        pool = BlockPool(4, 2)
        a = idx.extend(idx.root, (1, 2), pool.alloc())
        b = idx.extend(a, (3, 4), pool.alloc())
        with pytest.raises(RuntimeError):
            idx.remove(a)                 # internal node
        idx.remove(b)
        idx.remove(a)                     # now a leaf
        assert len(idx) == 0


# ---------------------------------------------------------------------------
# cache facade
# ---------------------------------------------------------------------------

class TestPrefixKVCache:
    def test_lookup_insert_shared_prefix(self):
        c = PrefixKVCache(16, 4)
        sys_p = tuple(range(8))
        c.insert(sys_p + (50, 51, 52, 53))
        c.insert(sys_p + (60, 61, 62, 63))
        # the shared 8-token prefix is stored once: 2 + 1 + 1 blocks
        assert c.pool.n_used == 4
        hit = c.lookup(sys_p + (70, 71, 72, 73))
        assert hit.n_tokens == 8
        c.release(hit)

    def test_lookup_pins_blocks_against_eviction(self):
        c = PrefixKVCache(2, 4)
        p1 = tuple(range(8))              # fills the pool
        c.insert(p1)
        hit = c.lookup(p1, max_tokens=len(p1) - 1)
        assert hit.n_tokens == 4          # capped to leave a suffix
        # p1's unpinned tail block is evictable, the pinned head is not: a
        # conflicting insert admits one block then gets rejected, and must
        # never free KV the outstanding hit is reading
        p2 = (99,) + tuple(range(100, 107))
        assert c.insert(p2) == 1
        assert c.stats["rejected"] == 1
        assert c.peek(p1) == 4            # pinned head survived
        assert hit.blocks[0].in_use and hit.blocks[0].refcount == 1
        c.release(hit)
        assert c.insert(p2) == 1          # evictable now
        assert c.stats["evictions"] == 2
        assert c.peek(p2) == 8

    def test_release_makes_hit_inert(self):
        c = PrefixKVCache(4, 4)
        c.insert(tuple(range(8)))
        hit = c.lookup(tuple(range(8)))
        c.release(hit)
        assert not hit and hit.blocks == []
        assert all(b.refcount == 0 for b in c.pool.blocks)

    def test_eviction_prefers_low_value(self):
        now = [0.0]
        c = PrefixKVCache(2, 4, clock_fn=lambda: now[0])
        c.insert(tuple(range(4)))         # block A at t=0
        now[0] = 100.0
        c.insert(tuple(range(100, 104)))  # block B at t=100
        h = c.lookup(tuple(range(100, 104)))   # B hit: more valuable
        c.release(h)
        now[0] = 101.0
        c.insert(tuple(range(200, 204)))  # must evict stale A, not hot B
        assert c.peek(tuple(range(100, 104))) == 4
        assert c.peek(tuple(range(4))) == 0

    def test_insert_larger_than_pool(self):
        c = PrefixKVCache(3, 2)
        added = c.insert(tuple(range(10)))     # 5 spans, 3 slots
        assert added == 3                      # strict left-to-right prefix
        assert c.peek(tuple(range(10))) == 6

    def test_payload_fn_called_only_for_new_blocks(self):
        calls = []
        c = PrefixKVCache(8, 4)
        c.insert(tuple(range(8)), lambda s, e: calls.append((s, e)))
        c.insert(tuple(range(12)), lambda s, e: calls.append((s, e)))
        assert calls == [(0, 4), (4, 8), (8, 12)]


# ---------------------------------------------------------------------------
# PREFIX merge level
# ---------------------------------------------------------------------------

def test_prefix_merge_level():
    assert MergeLevel.PREFIX < MergeLevel.DATA_ONLY
    assert MergeLevel.PREFIX.label == "prefix"
    assert common_prefix_len((1, 2, 3, 4), (1, 2, 9)) == 2
    assert common_prefix_len((1, 2), (1, 2, 3)) == 2
    assert common_prefix_len((9,), (1,)) == 0


# ---------------------------------------------------------------------------
# simulator analytical model
# ---------------------------------------------------------------------------

def _prefix_tasks(n=200, n_prefixes=6, seed=0):
    rng = np.random.default_rng(seed)
    prefixes = [tuple(rng.integers(0, 1000, size=48).tolist())
                for _ in range(n_prefixes)]
    out, t = [], 0.0
    for i in range(n):
        pi = min(int(rng.zipf(1.5)) - 1, n_prefixes - 1)
        toks = prefixes[pi] + tuple(rng.integers(0, 1000, size=16).tolist())
        out.append(Task(ttype="generate", data_id=f"d{i}", op="generate",
                        arrival=t, deadline=t + 400, tokens=toks))
        t += float(rng.exponential(4))
    return out


def _run_sim(blocks, seed=0):
    rng = np.random.default_rng(7)
    pet = PETMatrix.generate(["generate"], ["m0"], rng, mean_range=(15, 25))
    sim = Simulator(_prefix_tasks(seed=seed),
                    [Machine(mid=i) for i in range(3)],
                    PETOracle(pet, seed=3),
                    SimConfig(prefix_cache_blocks=blocks, kv_block_size=16))
    return sim.run()


class TestSimulatorPrefixReuse:
    def test_disabled_by_default(self):
        st = _run_sim(0)
        assert st.prefix_hits == 0 and st.prefix_time_saved == 0.0

    def test_reuse_saves_time_and_scales_with_capacity(self):
        st0 = _run_sim(0)
        st_small = _run_sim(8)
        st_big = _run_sim(128)
        assert st_small.prefix_hits > 0
        assert st_big.prefix_hits >= st_small.prefix_hits
        assert st_big.busy_time < st_small.busy_time < st0.busy_time
        assert st_small.prefix_evictions > 0
        assert st_big.prefix_tokens_reused >= st_small.prefix_tokens_reused

    def test_no_dangling_refs_after_run(self):
        rng = np.random.default_rng(7)
        pet = PETMatrix.generate(["generate"], ["m0"], rng)
        sim = Simulator(_prefix_tasks(n=80), [Machine(mid=0)],
                        PETOracle(pet, seed=3),
                        SimConfig(prefix_cache_blocks=16, kv_block_size=16))
        sim.run()
        assert all(b.refcount == 0 for b in sim.kvcache.pool.blocks)
