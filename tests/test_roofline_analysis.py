"""Validation of the trip-count-aware HLO cost analysis (the §Roofline
measurement tool): exact against XLA's cost_analysis on loop-free modules
and against hand counts on scan/remat/grad compositions."""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.models.layers import flash_attention, full_attention
from repro.parallel.hlo_cost import analyze_text, parse_module
from repro.parallel.roofline import Roofline

SDS = jax.ShapeDtypeStruct


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


class TestFlopCounting:
    def test_matmul_exact(self):
        c = _compile(lambda a, b: a @ b, SDS((256, 512), jnp.float32),
                     SDS((512, 128), jnp.float32))
        got = analyze_text(c.as_text()).flops
        assert got == 2 * 256 * 512 * 128

    def test_full_attention_matches_xla(self):
        q = SDS((2, 128, 4, 32), jnp.float32)
        c = _compile(lambda q, k, v: full_attention(q, k, v, causal=True),
                     q, q, q)
        got = analyze_text(c.as_text()).flops
        want = 2 * 2 * (2 * 128 * 128 * 4 * 32)   # scores + values
        assert got == want

    def test_flash_loops_counted(self):
        """XLA's cost_analysis counts loop bodies once; ours multiplies by
        the trip count and recovers the loop-free total."""
        q = SDS((2, 128, 4, 32), jnp.float32)
        c = _compile(lambda q, k, v: flash_attention(q, k, v, causal=True,
                                                     q_block=32, kv_block=32),
                     q, q, q)
        got = analyze_text(c.as_text()).flops
        want = 2 * 2 * (2 * 128 * 128 * 4 * 32)
        assert got == want
        xla = c.cost_analysis()
        xla = xla[0] if isinstance(xla, list) else xla
        assert float(xla["flops"]) < want / 2     # XLA's known undercount

    def test_scan_remat_grad(self):
        def loss(x, ws):
            @jax.checkpoint
            def blk(h, w):
                return jnp.tanh(h @ w)
            h, _ = lax.scan(lambda c, w: (blk(c, w), None), x, ws)
            return h.sum()
        c = _compile(jax.grad(loss, argnums=1),
                     SDS((64, 128), jnp.float32),
                     SDS((4, 128, 128), jnp.float32))
        got = analyze_text(c.as_text()).flops
        # fwd(1x) + remat fwd(1x) + bwd(2x) = 4x per layer
        want = 2 * 64 * 128 * 128 * 4 * 4
        assert got == pytest.approx(want, rel=0.01)

    def test_nested_scans(self):
        def f(x, w):
            def outer(c, _):
                c, _ = lax.scan(lambda d, __: (d @ w, None), c, None,
                                length=3)
                return c, None
            return lax.scan(outer, x, None, length=5)[0]
        c = _compile(f, SDS((128, 128), jnp.float32),
                     SDS((128, 128), jnp.float32))
        assert analyze_text(c.as_text()).flops == 2 * 128 ** 3 * 15


class TestCollectives:
    def test_sharded_matmul_allgather(self):
        if jax.device_count() < 4:
            pytest.skip("needs >=4 devices (run under DRYRUN_DEVICES)")
        from jax.sharding import NamedSharding, PartitionSpec as P
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        h = jax.jit(lambda a, b: a @ b,
                    in_shardings=(NamedSharding(mesh, P("data", None)),
                                  NamedSharding(mesh, P(None, "model"))),
                    out_shardings=NamedSharding(mesh, P("data", None)))
        c = h.lower(SDS((256, 256), jnp.float32),
                    SDS((256, 256), jnp.float32)).compile()
        cost = analyze_text(c.as_text())
        assert cost.flops == 2 * 256 ** 3 / 4          # per-chip share
        assert cost.collectives.get("all-gather", 0) > 0


class TestRooflineModel:
    def test_terms_and_bottleneck(self):
        r = Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                     collective_bytes=50e9 * 0.5, collectives={},
                     collective_counts={}, model_flops_total=197e12 * 256,
                     chips=256)
        assert r.t_compute == pytest.approx(1.0)
        assert r.t_memory == pytest.approx(2.0)
        assert r.t_collective == pytest.approx(0.5)
        assert r.bottleneck == "memory"
        assert r.step_time == pytest.approx(2.0)
        assert r.mfu_roofline == pytest.approx(0.5)
        assert r.useful_flops_ratio == pytest.approx(1.0)

    def test_parse_module_handles_tuple_comments(self):
        txt = """
HloModule test

ENTRY %main (p: f32[4,4]) -> f32[4,4] {
  %p = f32[4,4]{1,0} parameter(0)
  %t = (f32[4,4]{1,0}, /*index=1*/f32[4,4]{1,0}) tuple(%p, %p)
  ROOT %g = f32[4,4]{1,0} get-tuple-element(%t), index=0
}
"""
        comps, entry = parse_module(txt)
        assert entry == "main"
        assert len(comps["main"].instrs) == 3
