"""Front-door Cluster/Router coverage (DESIGN.md §2.6): the single-plane
oracle equivalence (a 1-plane Router must reproduce the bare engine's
decision sequence and QoS exactly), streaming admission, cross-plane
dedup/prefix-affinity routing, mixed-kind planes, the router-policy
registry, and the config field-roundtrips.  Stub execution throughout —
no JAX math in this file."""

import numpy as np
import pytest

from repro.core.controlplane import ControlConfig
from repro.core.heuristics import MappingContext
from repro.core.pruning import PruningConfig
from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.tasks import Machine, PETMatrix, Task
from repro.serving.cluster import (ROUTER_POLICIES, Plane, Router,
                                   RouterPolicy, make_router_policy)
from repro.serving.engine import EngineConfig, Request, ServingEngine


def _pet(seed=3, mean_range=(8, 16)):
    rng = np.random.default_rng(seed)
    return PETMatrix.generate(["generate"], ["m0"], rng,
                              mean_range=mean_range)


def _request_trace(n=40, seed=1, n_prompts=4, deadline=200.0, gap=1.0):
    rng = np.random.default_rng(seed)
    prompts = [tuple(rng.integers(1, 1000, size=8).tolist())
               for _ in range(n_prompts)]
    out, t = [], 0.0
    for _ in range(n):
        out.append((t, Request(
            prompt=prompts[int(rng.integers(0, n_prompts))], op="generate",
            n_new=int(rng.integers(1, 4)), seed=int(rng.integers(0, 2)),
            deadline=t + deadline)))
        t += float(rng.exponential(gap))
    return out


def _stub_engine(pet, n_units=2, **cfg_kw):
    cfg_kw.setdefault("heuristic", "EDF")
    cfg_kw.setdefault("merging", "adaptive")
    return ServingEngine(None, None, EngineConfig(
        n_units=n_units, elasticity=None,
        result_cache=False, prefix_cache=False, **cfg_kw),
        stub_oracle=PETOracle(pet, seed=11))


# ---------------------------------------------------------------------------
# registries + config roundtrips (mirrors the heuristics-registry coverage)
# ---------------------------------------------------------------------------

class TestRegistries:
    def test_router_policy_registry_names(self):
        assert {"round-robin", "least-loaded", "affinity"} <= \
            set(ROUTER_POLICIES)
        for name in ROUTER_POLICIES:
            pol = make_router_policy(name)
            assert isinstance(pol, RouterPolicy) and pol.name == name

    def test_router_policy_case_insensitive(self):
        assert make_router_policy("AFFINITY").name == "affinity"

    def test_unknown_router_policy_message(self):
        with pytest.raises(KeyError, match=r"unknown router policy 'bogus'"):
            make_router_policy("bogus")
        # the error must name the valid options, like make_heuristic's
        with pytest.raises(KeyError, match="affinity"):
            make_router_policy("bogus")

    def test_engine_config_control_roundtrip(self):
        prune = PruningConfig(initial_defer_threshold=0.2,
                              base_drop_threshold=0.07)
        ecfg = EngineConfig(heuristic="MSD", merging="conservative",
                            position_finder="log", pruning=prune,
                            alpha=1.5, merge_degree_cap=7)
        cc = ecfg.control()
        assert isinstance(cc, ControlConfig)
        assert (cc.heuristic, cc.merging, cc.position_finder) == \
            ("MSD", "conservative", "log")
        assert cc.pruning is prune
        assert cc.alpha == 1.5 and cc.merge_degree_cap == 7
        assert cc.hard_deadlines          # rides with pruning
        assert not EngineConfig(pruning=None).control().hard_deadlines

    def test_sim_config_control_roundtrip(self):
        scfg = SimConfig(heuristic="MU", merging="aggressive",
                         position_finder="linear", hard_deadlines=True,
                         alpha=0.5, merge_degree_cap=3)
        cc = scfg.control()
        assert (cc.heuristic, cc.merging, cc.position_finder,
                cc.hard_deadlines, cc.alpha, cc.merge_degree_cap) == \
            ("MU", "aggressive", "linear", True, 0.5, 3)


# ---------------------------------------------------------------------------
# single-plane oracle equivalence
# ---------------------------------------------------------------------------

class TestSinglePlaneEquivalence:
    @pytest.mark.parametrize("policy", sorted(ROUTER_POLICIES))
    def test_router_reproduces_bare_engine(self, policy):
        """The acceptance criterion: decision trace and QoS tuple of a
        1-plane Router over the stub engine == the bare ServingEngine on
        the same trace and oracle, for every registered policy."""
        pet = _pet()
        bare = _stub_engine(pet)
        bare.cp.trace = []
        s_bare = bare.run(_request_trace())

        eng = _stub_engine(pet)
        eng.cp.trace = []
        router = Router([Plane(eng)], policy=policy)
        s_r = router.run(_request_trace())

        assert eng.cp.trace == bare.cp.trace
        assert (s_r["on_time"], s_r["missed"], s_r["dropped"]) == \
            (s_bare["on_time"], s_bare["missed"], s_bare["dropped"])
        assert s_r["merges"] == s_bare["merges"] > 0
        assert s_r["merge_rejected"] == s_bare["merge_rejected"]
        assert s_r["executions"] == s_bare["executions"]
        assert s_r["deadlock_breaks"] == 0

    def test_streaming_matches_closed_trace_under_pruning(self):
        """submit/step/drain (explicit stepping past completions) must take
        the same decisions as the closed-trace wrapper, including on a
        drop-heavy pruned configuration."""
        kw = dict(heuristic="MSD", merging="conservative",
                  pruning=PruningConfig(initial_defer_threshold=0.1,
                                        base_drop_threshold=0.05,
                                        dynamic_defer=True))
        pet = _pet()
        bare = _stub_engine(pet, n_units=1, **kw)
        bare.cp.trace = []
        s_bare = bare.run(_request_trace(deadline=20.0, gap=0.5))

        eng = _stub_engine(pet, n_units=1, **kw)
        eng.cp.trace = []
        router = Router([Plane(eng)], policy="least-loaded")
        for t, req in _request_trace(deadline=20.0, gap=0.5):
            router.submit(req, t)
            router.step(t)        # an extra, coarser step changes nothing
        s_r = router.drain()

        assert s_bare["dropped"] > 0          # the drop path really ran
        assert eng.cp.trace == bare.cp.trace
        assert (s_r["on_time"], s_r["missed"], s_r["dropped"]) == \
            (s_bare["on_time"], s_bare["missed"], s_bare["dropped"])

    def test_out_of_order_trace_matches_bare_engine(self):
        """The bare engine's event heap reorders a non-monotonic trace;
        the closed-trace wrapper must too (it sorts before streaming),
        or a late-submitted early arrival is admitted at an already-
        advanced plane clock and spuriously misses its deadline."""
        def ooo_trace():
            return [(100.0, Request(prompt=(1, 2, 3, 4), op="generate",
                                    n_new=2, deadline=180.0)),
                    (200.0, Request(prompt=(5, 6, 7, 8), op="generate",
                                    n_new=2, deadline=280.0)),
                    (50.0, Request(prompt=(9, 10, 11, 12), op="generate",
                                   n_new=2, deadline=80.0))]

        pet = _pet()
        bare = _stub_engine(pet)
        bare.cp.trace = []
        s_bare = bare.run(ooo_trace())

        eng = _stub_engine(pet)
        eng.cp.trace = []
        s_r = Router([Plane(eng)], policy="least-loaded").run(ooo_trace())
        assert eng.cp.trace == bare.cp.trace
        assert (s_r["on_time"], s_r["missed"], s_r["dropped"]) == \
            (s_bare["on_time"], s_bare["missed"], s_bare["dropped"])
        assert s_r["missed"] == 0


# ---------------------------------------------------------------------------
# cross-plane routing
# ---------------------------------------------------------------------------

class TestCrossPlaneRouting:
    def test_shared_detector_dedup_affinity(self):
        """Duplicates of a hot prompt route to the plane holding the live
        merge target and actually merge there."""
        pet = _pet()
        planes = [Plane(_stub_engine(pet, n_units=1), pid=i)
                  for i in range(2)]
        router = Router(planes, policy="affinity")
        stats = router.run(_request_trace(gap=0.5))
        assert stats["router"]["affinity_hits"] > 0
        assert stats["merges"] > 0
        assert any(r.startswith("affinity:") for _, _, r in router.decisions)
        assert stats["completed"] + stats["dropped"] == 40
        assert stats["deadlock_breaks"] == 0

    def test_per_plane_detector_is_blind(self):
        """shared_detector=False: the affinity policy sees no cross-plane
        similarity and degrades to pure load balancing."""
        pet = _pet()
        planes = [Plane(_stub_engine(pet, n_units=1), pid=i)
                  for i in range(2)]
        router = Router(planes, policy="affinity", shared_detector=False)
        stats = router.run(_request_trace(gap=0.5))
        assert stats["router"]["affinity_hits"] == 0
        assert {r for _, _, r in router.decisions} == {"load"}
        assert stats["completed"] + stats["dropped"] == 40

    def test_prefix_affinity_on_simulator_planes(self):
        """Prefix-overlapping tasks route to the plane whose paged KV cache
        holds their blocks (the cross-plane PREFIX level, payload-free)."""
        pet = _pet()

        def sim_plane(pid):
            sim = Simulator([], [Machine(mid=1, mtype="m0", queue_size=4)],
                            PETOracle(pet, seed=5 + pid),
                            SimConfig(heuristic="EDF",
                                      prefix_cache_blocks=64,
                                      kv_block_size=16))
            return Plane(sim, pid=pid)

        router = Router([sim_plane(0), sim_plane(1)], policy="affinity")
        sys_prompt = tuple(range(1, 33))
        rng = np.random.default_rng(0)
        t = 0.0
        for i in range(10):
            toks = sys_prompt + tuple(rng.integers(100, 200, size=8).tolist())
            router.submit(Task(ttype="generate", data_id=f"d{i}",
                               op="generate", params=(), arrival=t,
                               deadline=t + 500.0, tokens=toks), t)
            t += 40.0       # past each completion: the cache is warm
        stats = router.drain()
        assert stats["router"]["prefix_affinity"] > 0
        assert stats["prefix_hits"] > 0
        # every post-warmup arrival followed the cached prefix to one plane
        routed = stats["router"]["routed"]
        assert max(routed.values()) >= 9
        assert stats["on_time"] == stats["n_requests"] == 10

    def test_round_robin_spreads(self):
        pet = _pet()
        planes = [Plane(_stub_engine(pet, n_units=1), pid=i)
                  for i in range(4)]
        router = Router(planes, policy="round-robin")
        stats = router.run(_request_trace(n=16))
        assert set(stats["router"]["routed"].values()) == {4}

    def test_mixed_kind_planes_one_front_door(self):
        """An engine plane and a simulator plane behind one router: the
        Request payload is adapted per plane kind and the two stat
        vocabularies are bridged, so the established aggregate invariants
        (completed + dropped == n_requests == n) hold for mixed clusters."""
        pet = _pet()
        sim = Simulator([], [Machine(mid=1, mtype="m0", queue_size=4)],
                        PETOracle(pet, seed=9), SimConfig(heuristic="EDF"))
        router = Router([Plane(_stub_engine(pet, n_units=1), pid=0),
                         Plane(sim, pid=1)], policy="round-robin")
        n = 12
        stats = router.run(_request_trace(n=n))
        assert stats["n_requests"] == n
        assert stats["completed"] + stats["dropped"] == n
        eng_stats, sim_stats = stats["planes"]
        # both vocabularies present on every plane row
        assert eng_stats["n_requests"] == n // 2
        assert sim_stats["n_requests"] == n // 2
        assert sim_stats["completed"] == \
            sim_stats["on_time"] + sim_stats["missed"]

    def test_affinity_spill_bounds_herding(self):
        """Pure locality-first herds every hot-prefix request onto the
        caching plane; a spill bound diverts arrivals once the imbalance
        exceeds it."""
        from repro.serving.cluster import AffinityRouter
        pet = _pet(mean_range=(50, 60))     # slow service: load builds up

        def planes():
            out = []
            for pid in range(2):
                sim = Simulator([], [Machine(mid=1, mtype="m0",
                                             queue_size=8)],
                                PETOracle(pet, seed=5 + pid),
                                SimConfig(heuristic="EDF",
                                          prefix_cache_blocks=64,
                                          kv_block_size=16))
                out.append(Plane(sim, pid=pid))
            return out

        def drive(policy):
            router = Router(planes(), policy=policy)
            sys_prompt = tuple(range(1, 33))
            rng = np.random.default_rng(0)
            t = 0.0
            for i in range(16):
                toks = sys_prompt + tuple(rng.integers(100, 200,
                                                       size=8).tolist())
                router.submit(Task(ttype="generate", data_id=f"d{i}",
                                   op="generate", params=(), arrival=t,
                                   deadline=t + 1e6, tokens=toks), t)
                t += 20.0   # ~1/3 service time: queue builds when herding
            return router.collect_stats()["router"]["routed"]

        herded = drive(AffinityRouter())
        spilled = drive(AffinityRouter(spill=1))
        assert max(herded.values()) > max(spilled.values())
        assert min(spilled.values()) > min(herded.values())

    def test_engine_plane_rejects_bare_tasks(self):
        router = Router([Plane(_stub_engine(_pet()))])
        with pytest.raises(TypeError, match="Requests"):
            router.submit(Task(ttype="generate", data_id="d", op="generate"),
                          0.0)

    def test_duplicate_plane_ids_rejected(self):
        pet = _pet()
        with pytest.raises(ValueError, match="unique"):
            Router([Plane(_stub_engine(pet), pid=0),
                    Plane(_stub_engine(pet), pid=0)])


# ---------------------------------------------------------------------------
# the shared locality term at the heuristics level
# ---------------------------------------------------------------------------

class TestMappingLocalityTerm:
    def test_prefix_overlap_breaks_availability_ties(self):
        """Two idle machines, per-machine prefix scores: the sorted-dispatch
        family must send the task to the machine holding its blocks."""
        from repro.core.heuristics import make_heuristic
        pet = _pet()
        oracle = PETOracle(pet, seed=0)
        machines = [Machine(mid=0, mtype="m0"), Machine(mid=1, mtype="m0")]
        task = Task(ttype="generate", data_id="d", op="generate",
                    tokens=tuple(range(32)), deadline=1e6)
        ctx = MappingContext(
            oracle=oracle,
            prefix_fn=lambda t, m: 16 if m.mid == 1 else 0)
        mapped = make_heuristic("EDF").map_batch([task], machines, ctx)
        assert mapped == [(task, machines[1])]
        assert ctx.prefix_overlap(task, machines[1]) == 16

    def test_no_prefix_fn_means_zero_and_first_machine(self):
        pet = _pet()
        ctx = MappingContext(oracle=PETOracle(pet, seed=0))
        machines = [Machine(mid=0, mtype="m0"), Machine(mid=1, mtype="m0")]
        task = Task(ttype="generate", data_id="d", op="generate",
                    deadline=1e6)
        from repro.core.heuristics import make_heuristic
        mapped = make_heuristic("EDF").map_batch([task], machines, ctx)
        assert mapped == [(task, machines[0])]
        assert ctx.prefix_overlap(task, machines[0]) == 0
