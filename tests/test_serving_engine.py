"""SMSE serving-engine coverage: merge-level semantics, result-cache path,
and the paged KV prefix cache end to end (hit/evict/refcount + the
token-identity and fewer-prefill-tokens acceptance criteria)."""

import jax
import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.core.fleet import FleetSpec
from repro.models import transformer as T
from repro.serving.engine import EngineConfig, Request, ServingEngine

KEY = jax.random.PRNGKey(0)


def _model(vocab=128):
    cfg = ARCHS["smollm-360m"].reduced().scaled(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab=vocab, head_dim=32, remat=False)
    return cfg, T.init_params(cfg, KEY)


_CFG, _PARAMS = _model()


def _engine(**kw):
    kw.setdefault("n_units", 1)
    kw.setdefault("elasticity", None)
    kw.setdefault("merging", "none")
    kw.setdefault("pruning", None)
    kw.setdefault("result_cache", False)
    kw.setdefault("max_len", 96)
    kw.setdefault("batch_buckets", (1, 2, 4))
    return ServingEngine(_CFG, _PARAMS, EngineConfig(**kw))


# ---------------------------------------------------------------------------
# merge levels
# ---------------------------------------------------------------------------

class TestMergeLevels:
    def test_task_level_fanout_identical_tokens(self):
        """Identical (prompt, op, params): one execution serves everyone."""
        eng = _engine(merging="aggressive")
        p = (3, 1, 4, 1, 5, 9, 2, 6)
        reqs = [Request(prompt=p, n_new=3, seed=0, deadline=1e9)
                for _ in range(4)]
        stats = eng.run([(0.0, r) for r in reqs])
        assert stats["executions"] == 1
        assert stats["merges"] == 3
        assert len(reqs[0].tokens) == 3
        assert all(r.tokens == reqs[0].tokens for r in reqs)

    def test_greedy_seed_normalized_into_task_level(self):
        """temperature==0 decoding ignores the seed, so identical greedy
        requests with different seeds must TASK-merge into one execution."""
        eng = _engine(merging="aggressive")
        p = (2, 7, 1, 8, 2, 8)
        reqs = [Request(prompt=p, n_new=2, temperature=0.0, seed=s,
                        deadline=1e9) for s in (0, 1, 2)]
        stats = eng.run([(0.0, r) for r in reqs])
        assert stats["executions"] == 1
        assert stats["merges"] == 2
        assert all(r.tokens == reqs[0].tokens for r in reqs)
        assert stats["deadlock_breaks"] == 0

    def test_sampled_seed_still_distinguishes(self):
        """temperature>0 requests keep the seed in their signature (they
        are DATA_OP, not TASK, so each gets its own sampled trajectory)."""
        r1 = Request(prompt=(1, 2, 3), n_new=2, temperature=0.8, seed=0)
        r2 = Request(prompt=(1, 2, 3), n_new=2, temperature=0.8, seed=1)
        assert r1.params_sig != r2.params_sig
        g1 = Request(prompt=(1, 2, 3), n_new=2, temperature=0.0, seed=0)
        g2 = Request(prompt=(1, 2, 3), n_new=2, temperature=0.0, seed=1)
        assert g1.params_sig == g2.params_sig

    def test_data_op_respects_per_request_n_new(self):
        """Same prompt + op, different params: shared prefill, each request
        still gets exactly its own n_new tokens."""
        eng = _engine(merging="aggressive")
        p = (7, 8, 9, 10, 11)
        r1 = Request(prompt=p, n_new=4, seed=0, deadline=1e9)
        r2 = Request(prompt=p, n_new=2, seed=1, deadline=1e9)
        r3 = Request(prompt=p, n_new=1, seed=2, deadline=1e9)
        stats = eng.run([(0.0, r1), (0.0, r2), (0.0, r3)])
        assert stats["executions"] == 1
        assert [len(r.tokens) for r in (r1, r2, r3)] == [4, 2, 1]
        # greedy portions agree with the longest request's trajectory
        assert r2.tokens == r1.tokens[:2] and r3.tokens == r1.tokens[:1]


# ---------------------------------------------------------------------------
# result cache
# ---------------------------------------------------------------------------

class TestResultCache:
    def test_hit_path_serves_without_execution(self):
        eng = _engine(result_cache=True)
        p = (1, 2, 3, 4, 5, 6)
        r1 = Request(prompt=p, n_new=2, deadline=1e9)
        eng.run([(0.0, r1)])
        execs = eng.stats["executions"]
        r2 = Request(prompt=p, n_new=2, deadline=1e9)
        eng.run([(eng.clock, r2)])
        assert eng.stats["executions"] == execs      # no new execution
        assert eng.stats["cache_hits"] == 1
        assert r2.status == "done" and r2.tokens == r1.tokens

    def test_greedy_seed_normalized_hits(self):
        """A different seed on a greedy request must not bust the cache."""
        eng = _engine(result_cache=True)
        p = (9, 8, 7, 6, 5)
        r1 = Request(prompt=p, n_new=2, temperature=0.0, seed=3, deadline=1e9)
        eng.run([(0.0, r1)])
        r2 = Request(prompt=p, n_new=2, temperature=0.0, seed=9, deadline=1e9)
        eng.run([(eng.clock, r2)])
        assert eng.stats["cache_hits"] == 1
        assert r2.tokens == r1.tokens

    def test_param_mismatch_misses(self):
        eng = _engine(result_cache=True)
        p = (1, 2, 3, 4, 5, 6)
        r1 = Request(prompt=p, n_new=2, deadline=1e9)
        eng.run([(0.0, r1)])
        r2 = Request(prompt=p, n_new=3, deadline=1e9)   # different params
        eng.run([(eng.clock, r2)])
        assert eng.stats["cache_hits"] == 0
        assert eng.stats["executions"] == 2


# ---------------------------------------------------------------------------
# paged KV prefix cache (the acceptance workload)
# ---------------------------------------------------------------------------

def _shared_prefix_trace(n=64, n_sys=8, sys_len=64, suffix_len=8, seed=0):
    """64 requests over 8 distinct >=64-token system prompts with distinct
    user suffixes — the issue's acceptance workload."""
    rng = np.random.default_rng(seed)
    sys_prompts = [tuple(rng.integers(1, _CFG.vocab, size=sys_len).tolist())
                   for _ in range(n_sys)]
    out = []
    for i in range(n):
        p = sys_prompts[i % n_sys] + \
            tuple(rng.integers(1, _CFG.vocab, size=suffix_len).tolist())
        out.append((0.0, Request(prompt=p, n_new=2, deadline=1e9)))
    return out


class TestPrefixCache:
    def test_acceptance_shared_prefix_workload(self):
        """>0 prefix hits, token-identical to a cache-disabled run, and
        measurably fewer prefill tokens executed."""
        tr_on = _shared_prefix_trace()
        eng_on = _engine(prefix_cache=True, kv_block_size=16,
                         kv_cache_blocks=128)
        s_on = eng_on.run(tr_on)

        tr_off = _shared_prefix_trace()
        eng_off = _engine(prefix_cache=False)
        s_off = eng_off.run(tr_off)

        assert s_on["prefix_hits"] > 0
        assert s_on["prefill_tokens"] < s_off["prefill_tokens"]
        assert s_on["prefix_tokens_reused"] > 0
        assert s_on["completed"] == s_off["completed"] == 64
        # the event-driven loop must never hit the no-progress escape hatch
        assert s_on["deadlock_breaks"] == 0 == s_off["deadlock_breaks"]
        toks_on = [r.tokens for _, r in tr_on]
        toks_off = [r.tokens for _, r in tr_off]
        assert toks_on == toks_off
        # every request after the first per system prompt reuses >= 64 tokens
        assert s_on["prefix_hits"] == 64 - 8
        assert s_on["prefix_tokens_reused"] == (64 - 8) * 64

    def test_eviction_under_tiny_pool_keeps_results_exact(self):
        """A pool far smaller than the working set must evict (never a
        pinned block) and still produce exact results."""
        tr = _shared_prefix_trace(n=24, n_sys=4)
        eng = _engine(prefix_cache=True, kv_block_size=16, kv_cache_blocks=6)
        s = eng.run(tr)
        assert s["prefix_evictions"] > 0
        assert s["completed"] == 24
        assert all(b.refcount == 0 for b in eng.kvcache.pool.blocks)

        tr_off = _shared_prefix_trace(n=24, n_sys=4)
        eng_off = _engine(prefix_cache=False)
        eng_off.run(tr_off)
        assert [r.tokens for _, r in tr] == [r.tokens for _, r in tr_off]

    def test_refcount_invariant_during_run(self):
        """Pool-level guard: freeing a referenced block raises, and the
        engine never trips it across a full eviction-heavy trace."""
        eng = _engine(prefix_cache=True, kv_block_size=16, kv_cache_blocks=4)
        eng.run(_shared_prefix_trace(n=16, n_sys=4))
        pool = eng.kvcache.pool
        blk = next(b for b in pool.blocks if b.in_use)
        pool.incref(blk)
        with pytest.raises(RuntimeError, match="referenced"):
            pool.free(blk)
        pool.decref(blk)

    def test_prefix_candidates_scored_on_submit(self):
        """PREFIX-level similarity is visible to the admission gate once the
        cache holds a matching prefix."""
        sys_p = tuple(range(1, 33))
        eng = _engine(prefix_cache=True, kv_block_size=16,
                      kv_cache_blocks=16)
        r1 = Request(prompt=sys_p + (40, 41), n_new=1, deadline=1e9)
        eng.run([(0.0, r1)])
        r2 = Request(prompt=sys_p + (50, 51), n_new=1, deadline=1e9)
        eng.run([(eng.clock, r2)])
        assert eng.stats["prefix_candidates"] == 1
        assert eng.detector.find_prefix_overlap(sys_p + (60,)) == 32

    def test_per_unit_caches_attribute_hits_to_the_owning_unit(self):
        """Two units, shared-system-prompt traffic arriving one at a time:
        the per-unit locality term (MappingContext.prefix_overlap) steers
        every follow-up onto the unit that cached the prefix, so its cache
        takes all the hits and the other unit's cache stays cold — the
        within-engine discrimination per-unit caches exist for."""
        eng = _engine(n_units=2, prefix_cache=True, kv_block_size=16,
                      kv_cache_blocks=64)
        assert len(eng.kvcaches) == 2
        assert eng.kvcache is None          # no single engine-wide cache
        sys_p = tuple(range(1, 33))
        rng = np.random.default_rng(0)
        n = 6
        for _ in range(n):
            suffix = tuple(rng.integers(40, _CFG.vocab, size=4).tolist())
            r = Request(prompt=sys_p + suffix, n_new=1, deadline=1e9)
            eng.run([(eng.clock, r)])
        stats = eng.collect_stats()
        assert stats["prefix_hits"] == n - 1
        per_unit = sorted(c.stats["hits"] for c in eng.kvcaches.values())
        assert per_unit == [0, n - 1]       # one owner, zero strays
        # and the mapping layer reports the discrimination directly
        probe = Request(prompt=sys_p + (40, 41), deadline=1e9).to_task(0, 0)
        scores = sorted(eng._prefix_locality(probe, m)
                        for m in eng.machines)
        assert scores == [0, 32]

    def test_retired_unit_keeps_its_prefix_counters(self):
        """Retiring an idle unit must carry its cache counters into the
        engine totals — end-of-run prefix stats never shrink (mirrors the
        simulator's retired-eviction bookkeeping)."""
        from repro.serving.engine import _EngineUnitPool
        eng = _engine(n_units=2, prefix_cache=True, kv_block_size=16,
                      kv_cache_blocks=64)
        sys_p = tuple(range(1, 33))
        for i in range(4):
            r = Request(prompt=sys_p + (40 + i, 41 + i), n_new=1,
                        deadline=1e9)
            eng.run([(eng.clock, r)])
        before = eng.collect_stats()
        assert before["prefix_hits"] == 3
        pool = _EngineUnitPool(eng)
        assert pool.shrink(eng.clock) and pool.shrink(eng.clock)
        assert not eng.units
        after = eng.collect_stats()
        for k in ("prefix_hits", "prefix_tokens_reused", "prefix_lookups",
                  "prefix_inserts", "prefix_evictions"):
            assert after[k] == before[k], k

    def test_disabled_for_stateful_families(self):
        cfg = ARCHS["xlstm-125m"].reduced().scaled(
            n_layers=2, d_model=64, n_heads=2, remat=False)
        params = T.init_params(cfg, KEY)
        eng = ServingEngine(cfg, params, EngineConfig(
            n_units=1, elasticity=None, merging="none",
            pruning=None, result_cache=False, max_len=48,
            batch_buckets=(1,), prefix_cache=True))
        assert eng.kvcache is None
        r = Request(prompt=tuple(range(1, 20)), n_new=2, deadline=1e9)
        stats = eng.run([(0.0, r)])
        assert stats["completed"] == 1 and len(r.tokens) == 2


# ---------------------------------------------------------------------------
# heterogeneous fleet: mixed backends in one live pool (DESIGN.md §2.8)
# ---------------------------------------------------------------------------

class TestMixedBackendPool:
    def test_compiled_emulated_and_stub_units_in_one_pool(self):
        """One live pool mixing all three backend kinds: compiled and
        emulated units run real model steps (emulated on a slower virtual
        timeline), the stub row is an oracle-timed remote stand-in, and
        every request is accounted exactly once."""
        fleet = FleetSpec.parse(
            "tpu:1:1.0:1.0:compiled,cpu:1:0.25:0.2:emulated,"
            "remote:1:1.0:0.1:stub")
        eng = ServingEngine(_CFG, _PARAMS, EngineConfig(
            fleet=fleet, elasticity=None, merging="none",
            result_cache=False, prefix_cache=False, max_len=96,
            batch_buckets=(1, 2, 4)))
        assert [u.kind for u in eng.units] == \
            ["compiled", "emulated", "stub"]
        assert [m.speed for m in eng.machines] == [1.0, 0.25, 1.0]
        rng = np.random.default_rng(3)
        n = 9
        trace = [(6.0 * i, Request(
            prompt=tuple(rng.integers(1, _CFG.vocab, size=6).tolist()),
            n_new=2, deadline=1e9)) for i in range(n)]
        stats = eng.run(trace)
        assert stats["completed"] == n
        assert stats["executions"] == n
        assert stats["cost"] > 0.0
        # the model-backed units really produced tokens; a stub-run
        # request (if any landed there) is done with an empty payload
        done = [r for _, r in trace]
        assert all(r.status == "done" for r in done)
        assert any(len(r.tokens) == 2 for r in done)
