"""Closed-loop session workload subsystem (DESIGN.md §2.11): arrival
processes, multi-turn session pools, staged DAGs with residual-slack
propagation, per-tenant SLO accounting, the driver pump, and the
drain-termination bugfix.  Stub execution except the prefix-reuse
acceptance class at the bottom (compiled tiny model)."""

import numpy as np
import pytest

from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.tasks import Machine, PETMatrix
from repro.core.workload import spiky_hc_workload, video_streaming_workload
from repro.serving.cluster import Plane, Router
from repro.serving.engine import EngineConfig, ServingEngine
from repro.serving.workload import (BurstyProcess, DiurnalProcess,
                                    PoissonProcess, SessionConfig,
                                    SessionPool, SpikeSchedule, Stage,
                                    StagedConfig, StagedPool, TenantSpec,
                                    WorkloadDriver, mix64, parse_tenants,
                                    sample_think, unit_float)


def _pet(seed=3, mean_range=(8, 16)):
    rng = np.random.default_rng(seed)
    return PETMatrix.generate(["generate"], ["m0"], rng,
                              mean_range=mean_range)


def _stub_engine(pet, n_units=2, **cfg_kw):
    cfg_kw.setdefault("heuristic", "EDF")
    cfg_kw.setdefault("merging", "adaptive")
    return ServingEngine(None, None, EngineConfig(
        n_units=n_units, elasticity=None,
        result_cache=False, prefix_cache=False, **cfg_kw),
        stub_oracle=PETOracle(pet, seed=11))


_TENANTS = [TenantSpec("gold", share=0.3, slack=0.6, priority=1),
            TenantSpec("free", share=0.7, slack=1.2)]


# ---------------------------------------------------------------------------
# arrival processes + the deterministic draw primitive
# ---------------------------------------------------------------------------

class TestArrivals:
    def test_splitmix_draws_are_pure(self):
        """Every (seed, uid, turn) draw is order-independent: same inputs,
        same value, regardless of when it is evaluated."""
        assert mix64(7, 3, 1) == mix64(7, 3, 1)
        assert mix64(7, 3, 1) != mix64(7, 3, 2)
        us = [unit_float(0, i, 0) for i in range(2000)]
        assert all(0.0 <= u < 1.0 for u in us)
        assert 0.4 < sum(us) / len(us) < 0.6        # roughly uniform

    def test_sample_think_forms(self):
        assert sample_think(("const", 3.0), 0.5, 0.5) == 3.0
        u = sample_think(("uniform", 2.0, 8.0), 0.25, 0.0)
        assert 2.0 <= u <= 8.0
        e = sample_think(("exp", 4.0), 0.5, 0.0)
        assert e > 0.0
        ln = sample_think(("lognorm", 5.0, 0.5), 0.3, 0.7)
        assert ln > 0.0

    def test_poisson_iter_deterministic(self):
        a, b = PoissonProcess(), PoissonProcess()
        it1 = a.iter_times(np.random.default_rng(5), 0.5)
        it2 = b.iter_times(np.random.default_rng(5), 0.5)
        t1 = [next(it1) for _ in range(50)]
        t2 = [next(it2) for _ in range(50)]
        assert t1 == t2
        assert t1 == sorted(t1) and t1[0] > 0.0

    def test_diurnal_weight_shape(self):
        p = DiurnalProcess(cycle=100.0, peaks=((0.0, 25.0),), high=2.0)
        assert p.weight(10.0) == 2.0        # inside the high window
        assert p.weight(60.0) == 1.0        # base period
        assert p.weight(110.0) == 2.0       # periodic
        assert p.peak == 2.0

    def test_two_peak_diurnal(self):
        p = DiurnalProcess.two_peak(cycle=100.0)
        highs = [t for t in range(100) if p.weight(float(t)) > 1.0]
        assert highs                        # both peaks present
        # thinning respects the envelope: all accepted times exist
        times = list(_take(p.iter_times(np.random.default_rng(0), 1.0), 200))
        assert times == sorted(times)

    def test_bursty_and_spike_schedule(self):
        b = BurstyProcess(windows=((10.0, 20.0),), high=4.0)
        assert b.weight(15.0) == 4.0 and b.weight(5.0) == 1.0
        rng = np.random.default_rng(2)
        sched = SpikeSchedule.sample(rng, ["t0", "t1"], span=100.0)
        for key in ("t0", "t1"):
            ws = {sched.weight(key, float(t)) for t in range(100)}
            assert 4.0 in ws and 1.0 in ws  # spikes over a base rate
        assert sched.process("t0").weight(0.0) in (1.0, 4.0)


def _take(it, n):
    for _ in range(n):
        yield next(it)


# ---------------------------------------------------------------------------
# re-hosted Chapter 4/5 generators (back-compat wrappers)
# ---------------------------------------------------------------------------

class TestGenerators:
    def test_video_workload_deterministic(self):
        w1 = video_streaming_workload(80, seed=3)
        w2 = video_streaming_workload(80, seed=3)
        assert [t.arrival for t in w1.tasks] == [t.arrival for t in w2.tasks]
        assert [t.key_task_level() for t in w1.tasks] == \
            [t.key_task_level() for t in w2.tasks]
        assert len(w1.tasks) == 80 and w1.span == 600.0

    def test_hc_workload_deterministic(self):
        w1 = spiky_hc_workload(60, seed=11)
        w2 = spiky_hc_workload(60, seed=11)
        assert [t.arrival for t in w1.tasks] == [t.arrival for t in w2.tasks]
        assert [(t.ttype, t.deadline) for t in w1.tasks] == \
            [(t.ttype, t.deadline) for t in w2.tasks]
        assert [t.arrival for t in w1.tasks] == \
            sorted(t.arrival for t in w1.tasks)
        assert len(w1.machines) == 8


# ---------------------------------------------------------------------------
# tenancy
# ---------------------------------------------------------------------------

class TestTenancy:
    def test_parse_tenants(self):
        ts = parse_tenants("gold:1:0.5:1,free:3")
        assert [t.name for t in ts] == ["gold", "free"]
        assert ts[0].slack == 0.5 and ts[0].priority == 1
        assert ts[1].share == 3.0 and ts[1].slack == 1.0

    def test_share_split_is_deterministic(self):
        pool = SessionPool(SessionConfig(users=400, turns=1, seed=5),
                           tenants=_TENANTS)
        names = [pool._tenant(uid).name for uid in range(400)]
        gold = names.count("gold")
        assert 0.2 < gold / 400 < 0.4            # ~30% share
        assert names == [pool._tenant(uid).name for uid in range(400)]


# ---------------------------------------------------------------------------
# session pool semantics (no substrate)
# ---------------------------------------------------------------------------

class TestSessionPool:
    def test_prompt_prefix_invariant(self):
        """prompt(uid, k) extends prompt(uid, k-1) exactly — the invariant
        that makes multi-turn traffic exercise the prefix KV cache."""
        pool = SessionPool(SessionConfig(users=4, turns=5, seed=9))
        for uid in range(4):
            prev = pool.prompt(uid, 0)
            assert len(prev) == pool.cfg.base_prompt
            for k in range(1, 5):
                cur = pool.prompt(uid, k)
                assert cur[:len(prev)] == prev
                assert len(cur) == len(prev) + \
                    pool.cfg.n_new + pool.cfg.followup
                prev = cur
        # distinct users get distinct conversations
        assert pool.prompt(0, 2) != pool.prompt(1, 2)

    def test_pop_streams_starts_deterministically(self):
        def turn0(seed):
            pool = SessionPool(SessionConfig(users=10, turns=3, seed=seed))
            out = []
            while pool.pending():
                t, item = pool.pop()
                out.append((t, item.session, item.turn, item.prompt))
            return out

        a, b = turn0(4), turn0(4)
        assert a == b
        assert [x[2] for x in a] == [0] * 10     # only turn 0 without wakes
        assert turn0(5) != a

    def test_wakeup_rearrives_with_grown_prefix(self):
        pool = SessionPool(SessionConfig(users=1, turns=2, seed=1,
                                         think=("const", 3.0)))
        t0, item0 = pool.pop()
        assert pool.in_flight() == 1 and not pool.pending()
        pool.on_complete(item0, t0 + 5.0, "done")
        assert pool.pending()
        t1, item1 = pool.pop()
        assert t1 == t0 + 5.0 + 3.0              # completion + think time
        assert item1.turn == 1
        assert item1.prompt[:len(item0.prompt)] == item0.prompt

    def test_drop_aborts_session_by_default(self):
        pool = SessionPool(SessionConfig(users=1, turns=4, seed=1))
        t0, item0 = pool.pop()
        pool.on_complete(item0, t0 + 1.0, "dropped")
        assert not pool.pending() and pool.sessions_done == 1
        s = pool.summary()
        assert s["per_turn"][0]["dropped"] == 1
        assert s["tenants"]["default"]["dropped"] == 1

    def test_stale_completion_ignored(self):
        """Duplicate completion callbacks (merged compounds fan out per
        request) must not double-advance a session."""
        pool = SessionPool(SessionConfig(users=1, turns=3, seed=1))
        t0, item0 = pool.pop()
        pool.on_complete(item0, t0 + 1.0, "done")
        n_wake = len(pool._wake)
        pool.on_complete(item0, t0 + 2.0, "done")    # stale duplicate
        assert len(pool._wake) == n_wake
        assert pool.summary()["per_turn"][0]["completed"] == 1


# ---------------------------------------------------------------------------
# staged DAGs: residual-slack propagation
# ---------------------------------------------------------------------------

class TestStagedDAG:
    def test_stage_deadlines_carve_out_tail_estimates(self):
        """Stage i's admitted deadline is D - tail_est(i): earlier stages
        get earlier deadlines, the final stage gets the DAG deadline."""
        stages = (Stage(est=10.0), Stage(est=20.0), Stage(est=30.0))
        pool = StagedPool(StagedConfig(dags=1, stages=stages, slack=2.0,
                                       seed=3))
        assert pool.critical_path == 60.0
        assert pool.tails == [50.0, 30.0, 0.0]
        t0, item0 = pool.pop()
        D = pool._state[0]["deadline"]
        assert D == pytest.approx(t0 + 2.0 * 60.0)
        assert item0.deadline == pytest.approx(D - 50.0)

    def test_late_predecessor_shrinks_residual_slack(self):
        """The deadline is absolute: a slow stage 0 eats exactly its
        overrun out of stage 1's admission slack — the pruner sees the
        true remaining budget."""
        stages = (Stage(est=10.0), Stage(est=10.0))
        pool = StagedPool(StagedConfig(dags=1, stages=stages, slack=2.0,
                                       seed=3))
        t0, item0 = pool.pop()
        D = pool._state[0]["deadline"]
        pool.on_complete(item0, t0 + 35.0, "done")   # way past its est
        t1, item1 = pool.pop()
        assert t1 == t0 + 35.0                       # admitted at completion
        assert item1.deadline == pytest.approx(D)    # absolute, not reset
        slack1 = item1.deadline - t1
        assert slack1 == pytest.approx(2.0 * 20.0 - 35.0)
        s = pool.summary()
        assert s["per_stage"][1]["mean_slack_at_admit"] == \
            pytest.approx(slack1)

    def test_fan_in_waits_for_all_predecessors(self):
        """A join stage is admitted only when every prerequisite is done,
        at the last completion instant."""
        stages = (Stage(est=10.0, after=()), Stage(est=10.0, after=()),
                  Stage(est=10.0, after=(0, 1)))
        pool = StagedPool(StagedConfig(dags=1, stages=stages, seed=3))
        t0, a = pool.pop()          # root 0
        tb, b = pool.pop()          # root 1, ready at the same instant
        assert tb == t0 and {a.turn, b.turn} == {0, 1}
        pool.on_complete(a, t0 + 5.0, "done")
        assert not pool.pending()   # join still blocked on root 1
        pool.on_complete(b, t0 + 9.0, "done")
        tj, j = pool.pop()
        assert tj == t0 + 9.0 and j.turn == 2

    def test_drop_aborts_descendants(self):
        pool = StagedPool(StagedConfig(dags=1, seed=3))
        t0, item0 = pool.pop()
        pool.on_complete(item0, t0 + 1.0, "dropped")
        assert not pool.pending()
        s = pool.summary()
        assert s["dags_aborted"] == 1 and s["dags_done"] == 0
        assert s["per_stage"][1]["submitted"] == 0

    def test_staged_end_to_end_on_stub_engine(self):
        pet = _pet()
        eng = _stub_engine(pet, n_units=2)
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False)
        pool = StagedPool(StagedConfig(dags=6, arrival_rate=0.3, slack=4.0,
                                       seed=7), tenants=_TENANTS)
        stats = WorkloadDriver(router, pool).run()
        s = pool.summary()
        assert s["dags_done"] + s["dags_aborted"] == 6
        assert s["dags_done"] > 0
        submitted = sum(r["submitted"] for r in s["per_stage"])
        assert stats["completed"] + stats["dropped"] == submitted
        # stages were admitted in dependency order for every DAG
        assert s["per_stage"][0]["submitted"] >= \
            s["per_stage"][1]["submitted"] >= s["per_stage"][2]["submitted"]


# ---------------------------------------------------------------------------
# closed loop on the stub engine + the drain-termination bugfix
# ---------------------------------------------------------------------------

class TestClosedLoopStubEngine:
    def test_sessions_run_to_completion(self):
        pet = _pet()
        eng = _stub_engine(pet)
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False)
        pool = SessionPool(SessionConfig(users=10, turns=3, arrival_rate=0.4,
                                         deadline=150.0, seed=7), _TENANTS)
        stats = WorkloadDriver(router, pool).run()
        s = pool.summary()
        assert s["sessions_done"] == 10
        submitted = sum(r["submitted"] for r in s["per_turn"])
        assert stats["completed"] + stats["dropped"] == submitted
        assert s["per_turn"][0]["submitted"] == 10
        # tenant accounting is complete and consistent
        tens = s["tenants"]
        assert sum(t["submitted"] for t in tens.values()) == submitted
        for t in tens.values():
            assert t["completed"] + t["dropped"] <= t["submitted"]
            assert 0.0 <= t["on_time_rate"] <= 1.0

    def test_tenant_labels_reach_metrics(self):
        from repro.obs import Telemetry
        pet = _pet()
        eng = _stub_engine(pet)
        tel = Telemetry()
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False, telemetry=tel)
        pool = SessionPool(SessionConfig(users=6, turns=2, arrival_rate=0.4,
                                         deadline=150.0, seed=7), _TENANTS)
        WorkloadDriver(router, pool).run()
        snap = tel.metrics.snapshot()
        tenant_counters = [k for k in snap["counters"]
                           if k.startswith("tenant_completed{")]
        assert tenant_counters
        done = sum(snap["counters"][k] for k in tenant_counters)
        assert done == sum(t["completed"]
                           for t in pool.summary()["tenants"].values())
        # lifecycle events carry the tenant attribute
        tenants_seen = {e.get("tenant") for e in tel.events
                        if e["kind"] == "complete"}
        assert tenants_seen <= {"gold", "free"} and tenants_seen

    def test_drain_pumps_generator_dry(self):
        """The bugfix: Router.drain() with a closed-loop generator attached
        must alternate quiescence with generator pumping until the pool is
        exhausted — not return with sessions mid-flight, and not spin."""
        pet = _pet()
        eng = _stub_engine(pet)
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False)
        pool = SessionPool(SessionConfig(users=8, turns=3, arrival_rate=0.5,
                                         think=("const", 50.0),
                                         deadline=200.0, seed=2))
        WorkloadDriver(router, pool)      # attach without running the pump
        stats = router.drain()            # drain alone must finish the work
        assert pool.sessions_done == 8
        assert pool.in_flight() == 0 and not pool.pending()
        assert stats["completed"] + stats["dropped"] == \
            sum(r["submitted"] for r in pool.summary()["per_turn"])

    def test_drain_without_workload_unchanged(self):
        pet = _pet()
        eng = _stub_engine(pet)
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False)
        from repro.serving.engine import Request
        router.submit(Request(prompt=(1, 2, 3, 4), op="generate", n_new=2,
                              deadline=100.0), 0.0)
        stats = router.drain()
        assert stats["completed"] == 1


# ---------------------------------------------------------------------------
# sim <-> engine decision equivalence with sessions ON
# ---------------------------------------------------------------------------

class TestSessionEquivalence:
    def test_sim_matches_stub_engine_closed_loop(self):
        """The closed loop preserves the cross-substrate acceptance
        criterion: the same SessionPool config driving a simulator plane
        and a stub-engine plane yields identical decision traces."""
        pet = _pet()

        def make_pool():
            return SessionPool(SessionConfig(
                users=12, turns=4, arrival_rate=0.4,
                think=("uniform", 2.0, 6.0), deadline=150.0, seed=7),
                _TENANTS)

        eng = _stub_engine(pet)
        eng.cp.trace = []
        r1 = Router([Plane(eng, pid=0)], policy="round-robin",
                    shared_detector=False)
        d1 = WorkloadDriver(r1, make_pool())
        s1 = d1.run()

        sim = Simulator([], [Machine(mid=i) for i in range(2)],
                        PETOracle(pet, seed=11),
                        SimConfig(heuristic="EDF", merging="adaptive"))
        sim.cp.trace = []
        r2 = Router([Plane(sim, pid=0)], policy="round-robin",
                    shared_detector=False)
        d2 = WorkloadDriver(r2, make_pool())
        s2 = d2.run()

        assert eng.cp.trace and eng.cp.trace == sim.cp.trace
        assert d1.pool.sessions_done == d2.pool.sessions_done == 12
        assert s1["completed"] == s2["completed"]
        assert d1.pool.summary()["tenants"] == d2.pool.summary()["tenants"]


# ---------------------------------------------------------------------------
# bounded memory at scale (simulator fast path)
# ---------------------------------------------------------------------------

class TestScale:
    def test_active_sessions_bounded_far_below_users(self):
        """The streaming pool holds per-session state only while a session
        is in flight or thinking: peak_active_sessions stays a small
        fraction of the user population."""
        users = 3000
        pet = _pet(mean_range=(1, 2))
        sim = Simulator([], [Machine(mid=i, queue_size=64)
                             for i in range(8)],
                        PETOracle(pet, seed=11),
                        SimConfig(heuristic="EDF", merging="none"))
        router = Router([Plane(sim, pid=0)], policy="round-robin",
                        shared_detector=False)
        pool = SessionPool(SessionConfig(
            users=users, turns=2, arrival_rate=3.0, think=("const", 0.5),
            deadline=500.0, emit="task", n_new=1, seed=1))
        WorkloadDriver(router, pool).run()
        s = pool.summary()
        assert s["users"] == users and s["sessions_done"] == users
        assert s["peak_active_sessions"] < users / 4


# ---------------------------------------------------------------------------
# prefix-reuse acceptance on the live engine (compiled tiny model)
# ---------------------------------------------------------------------------

class TestLiveEnginePrefixReuse:
    @pytest.fixture(scope="class")
    def model(self):
        import jax
        from repro.configs.registry import ARCHS
        from repro.models import transformer as T
        cfg = ARCHS["smollm-360m"].reduced().scaled(
            n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
            vocab=256, head_dim=32, remat=False)
        return cfg, T.init_params(cfg, jax.random.PRNGKey(0))

    def _run(self, model, users, turns):
        cfg, params = model
        eng = ServingEngine(cfg, params, EngineConfig(
            n_units=1, elasticity=None, result_cache=False,
            prefix_cache=True, heuristic="EDF", merging="none",
            max_len=64, kv_block_size=4))
        router = Router([Plane(eng, pid=0)], policy="round-robin",
                        shared_detector=False)
        pool = SessionPool(SessionConfig(
            users=users, turns=turns, arrival_rate=0.2,
            think=("uniform", 5.0, 10.0), deadline=500.0, vocab=250,
            seed=7))
        stats = WorkloadDriver(router, pool, record_hit_depth=True).run()
        return stats, pool.summary()

    def test_turn_hit_depth_monotone_and_positive(self, model):
        """Acceptance: turn k's prefix hit depth >= turn k-1's for the
        multi-turn sessions, strictly positive once the cache is warm —
        each turn re-arrives with the grown prefix and finds the previous
        turn's KV blocks."""
        stats, s = self._run(model, users=3, turns=4)
        assert s["sessions_done"] == 3
        depths = [r["mean_hit_depth"] for r in s["per_turn"]]
        assert depths[0] == 0.0                  # cold start
        assert all(b >= a for a, b in zip(depths, depths[1:]))
        assert depths[-1] > 0.0
        assert stats["prefix_hits"] > 0
        assert stats["prefix_tokens_reused"] > 0

    def test_multi_turn_beats_single_shot_baseline(self, model):
        """Acceptance: the closed-loop multi-turn hit rate is strictly
        above the single-shot baseline (same arrival volume, turns=1:
        per-user prompts never repeat, so the prefix cache cannot help)."""
        multi, _ = self._run(model, users=3, turns=3)
        single, _ = self._run(model, users=9, turns=1)
        multi_rate = multi["prefix_hits"] / max(1, multi["executions"])
        single_rate = single["prefix_hits"] / max(1, single["executions"])
        assert multi_rate > single_rate
        assert single["prefix_hits"] == 0
