"""Heterogeneous-fleet coverage (DESIGN.md §2.8, no JAX anywhere):

* the FleetSpec catalog: parse/serialize roundtrip, validation, expansion,
  machine construction;
* homogeneous-fleet regression — an engine/simulator built from
  ``FleetSpec.homogeneous(n)`` takes decision traces identical to the
  legacy ``n_units``/explicit-machine construction (the pre-refactor
  behavior, preserved as the default path);
* hetero sim <-> stub-engine decision + cost equivalence from one shared
  FleetSpec (same PET keys by construction);
* the cost-aware mapping heuristics (MEC, MCMD);
* cheapest-first scale-up / priciest-first retirement and the per-mtype
  cost integrals (pool_cost);
* per-machine KV caches in the simulator (hit attribution + the per-unit
  ``MappingContext.prefix_overlap`` discrimination);
* the Eq. 4.3 OSL pressure signal as an ElasticityConfig-selectable
  alternative to the chance convolution.
"""

import numpy as np
import pytest

from repro.core.fleet import DEFAULT_MTYPE, FleetSpec, MachineSpec
from repro.core.heuristics import HEURISTICS, MappingContext, make_heuristic
from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.tasks import Machine, PETMatrix, Task
from repro.serving.autoscale import ElasticityConfig, ScaleSignals
from repro.serving.autoscale.policies import (CostAwareScaler,
                                              SuccessChanceScaler)
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.engine import _EngineUnitPool


def _pet(seed=0, mtypes=("m0",), mean_range=(10, 20), inconsistent=True):
    rng = np.random.default_rng(seed)
    return PETMatrix.generate(["generate"], list(mtypes), rng,
                              mean_range=mean_range,
                              inconsistent=inconsistent)


def _request_trace(n=40, seed=1, deadline=200.0, rate=0.5, n_prompts=5):
    rng = np.random.default_rng(seed)
    prompts = [tuple(rng.integers(1, 1000, size=8).tolist())
               for _ in range(n_prompts)]
    out, t = [], 0.0
    for _ in range(n):
        out.append((t, Request(
            prompt=prompts[int(rng.integers(0, n_prompts))], op="generate",
            n_new=int(rng.integers(1, 4)), seed=int(rng.integers(0, 2)),
            deadline=t + deadline)))
        t += float(rng.exponential(1.0 / rate))
    return out


def _mirror_tasks(trace):
    return [r.to_task(t, i) for i, (t, r) in enumerate(trace)]


# ---------------------------------------------------------------------------
# the catalog
# ---------------------------------------------------------------------------

class TestFleetSpec:
    def test_parse_serialize_roundtrip(self):
        text = "tpu:4:1:1:auto:4,cpu:4:0.25:0.2:auto:4"
        fleet = FleetSpec.parse(text)
        assert fleet.serialize() == text
        assert FleetSpec.parse(fleet.serialize()) == fleet

    def test_parse_defaults_and_optional_fields(self):
        fleet = FleetSpec.parse("fast:2,slow:1:0.5:0.25:stub:8")
        fast, slow = fleet.specs
        assert (fast.count, fast.speed, fast.cost_rate, fast.backend,
                fast.queue_size) == (2, 1.0, 1.0, "auto", 4)
        assert (slow.count, slow.speed, slow.cost_rate, slow.backend,
                slow.queue_size) == (1, 0.5, 0.25, "stub", 8)

    def test_parse_errors(self):
        with pytest.raises(ValueError, match="bad fleet row"):
            FleetSpec.parse("solo")
        with pytest.raises(ValueError, match="unknown backend"):
            FleetSpec.parse("a:1:1:1:warp")
        with pytest.raises(ValueError, match="count"):
            FleetSpec.parse("a:0")
        with pytest.raises(ValueError, match="mtype"):
            FleetSpec.parse(":2")
        with pytest.raises(ValueError, match="at least one"):
            FleetSpec(())

    def test_homogeneous_default_reproduces_todays_pool(self):
        fleet = FleetSpec.homogeneous(3)
        assert fleet.total == 3 and fleet.is_homogeneous
        machines = fleet.build_machines()
        assert [m.mid for m in machines] == [1, 2, 3]
        for m in machines:
            assert (m.mtype, m.speed, m.queue_size, m.cost_rate) == \
                (DEFAULT_MTYPE, 1.0, 4, 1.0)

    def test_expand_and_views(self):
        fleet = FleetSpec.parse("a:2:1:1.0,b:1:0.5:0.25")
        assert fleet.total == 3
        assert [s.mtype for s in fleet.expand()] == ["a", "a", "b"]
        assert all(s.count == 1 for s in fleet.expand())
        assert fleet.mtypes == ["a", "b"]
        assert not fleet.is_homogeneous
        assert fleet.cheapest().mtype == "b"
        assert fleet.cost_rate_total() == pytest.approx(2.25)

    def test_cheapest_tie_breaks_by_declaration_order(self):
        fleet = FleetSpec.parse("x:1:1:0.5,y:1:1:0.5")
        assert fleet.cheapest().mtype == "x"

    def test_build_machines_carries_every_field(self):
        fleet = FleetSpec((MachineSpec(mtype="z", count=1, speed=0.5,
                                       cost_rate=0.1, queue_size=7,
                                       power=0.3),))
        (m,) = fleet.build_machines(start_mid=5)
        assert (m.mid, m.mtype, m.speed, m.cost_rate, m.queue_size,
                m.power) == (5, "z", 0.5, 0.1, 7, 0.3)

    def test_power_survives_the_roundtrip(self):
        fleet = FleetSpec((MachineSpec(mtype="z", power=3.0),))
        assert fleet.serialize().endswith(":3")
        again = FleetSpec.parse(fleet.serialize())
        assert again == fleet and again.specs[0].power == 3.0
        assert FleetSpec.parse("z:1:1:1:auto:4:0.5").specs[0].power == 0.5


# ---------------------------------------------------------------------------
# homogeneous-fleet regression: fleet path == legacy construction
# ---------------------------------------------------------------------------

EQUIV_POLICIES = [
    dict(heuristic="EDF", merging="adaptive"),
    dict(heuristic="FCFS-RR", merging="aggressive"),
    dict(heuristic="MCT", merging="none"),
]


class TestHomogeneousRegression:
    @pytest.mark.parametrize("kw", EQUIV_POLICIES,
                             ids=[k["heuristic"] for k in EQUIV_POLICIES])
    def test_engine_fleet_matches_legacy_n_units(self, kw):
        """EngineConfig(fleet=homogeneous(n)) must take decision traces
        bitwise-identical to EngineConfig(n_units=n) — the pre-refactor
        construction, kept as the default."""
        pet = _pet(seed=3, mean_range=(8, 16))
        traces = []
        for fleet in (None, FleetSpec.homogeneous(2)):
            eng = ServingEngine(None, None, EngineConfig(
                n_units=2, fleet=fleet, elasticity=None,
                result_cache=False, prefix_cache=False, **kw),
                stub_oracle=PETOracle(pet, seed=11))
            eng.cp.trace = []
            stats = eng.run(_request_trace(n=40, seed=1))
            traces.append((eng.cp.trace, stats["on_time"], stats["missed"],
                           stats["dropped"], stats["cost"]))
        assert traces[0] == traces[1]

    def test_sim_fleet_matches_legacy_machines(self):
        pet = _pet(seed=3, mean_range=(8, 16))
        results = []
        for machines in ([Machine(mid=1, mtype=DEFAULT_MTYPE, queue_size=4),
                          Machine(mid=2, mtype=DEFAULT_MTYPE, queue_size=4)],
                         FleetSpec.homogeneous(2)):
            sim = Simulator(_mirror_tasks(_request_trace(n=40, seed=1)),
                            machines, PETOracle(pet, seed=11),
                            SimConfig(heuristic="EDF", merging="adaptive"))
            sim.cp.trace = []
            st = sim.run()
            results.append((sim.cp.trace, st.on_time, st.missed, st.dropped,
                            st.cost))
        assert results[0] == results[1]

    def test_engine_fleet_overrides_n_units(self):
        pet = _pet()
        eng = ServingEngine(None, None, EngineConfig(
            n_units=7, fleet=FleetSpec.homogeneous(2), elasticity=None,
            result_cache=False, prefix_cache=False),
            stub_oracle=PETOracle(pet, seed=1))
        assert len(eng.units) == 2


# ---------------------------------------------------------------------------
# heterogeneous sim <-> stub-engine equivalence (one FleetSpec, both sides)
# ---------------------------------------------------------------------------

MIXED = FleetSpec.parse("fast:2:1.0:1.0,slow:2:0.5:0.25")


class TestHeteroEquivalence:
    @pytest.mark.parametrize("heuristic", ["EDF", "MCT", "MCMD"])
    def test_same_fleet_same_decisions_and_cost(self, heuristic):
        """A mixed fast/slow fleet built from one FleetSpec: the stub
        engine and the simulator must take identical decision traces and
        report identical per-mtype execution cost."""
        pet = _pet(seed=3, mtypes=("fast", "slow"), mean_range=(8, 16),
                   inconsistent=False)
        trace = _request_trace(n=40, seed=1, deadline=250.0)

        eng = ServingEngine(None, None, EngineConfig(
            fleet=MIXED, heuristic=heuristic, merging="adaptive",
            elasticity=None, result_cache=False, prefix_cache=False),
            stub_oracle=PETOracle(pet, seed=11))
        eng.cp.trace = []
        stats = eng.run(trace)

        sim = Simulator(_mirror_tasks(trace), MIXED,
                        PETOracle(pet, seed=11),
                        SimConfig(heuristic=heuristic, merging="adaptive"))
        sim.cp.trace = []
        st = sim.run()

        assert sim.cp.trace == eng.cp.trace
        assert (st.on_time, st.missed, st.dropped) == \
            (stats["on_time"], stats["missed"], stats["dropped"])
        assert st.cost == pytest.approx(stats["cost"])
        assert st.pool_cost == pytest.approx(stats["pool_cost"])
        # the mixed fleet was actually exercised: both mtypes ran work
        used = {e[2] for e in eng.cp.trace if e[0] == "start"}
        assert {0, 1} & used and {2, 3} & used

    def test_engine_machines_mirror_fleet(self):
        pet = _pet(mtypes=("fast", "slow"))
        eng = ServingEngine(None, None, EngineConfig(
            fleet=MIXED, elasticity=None, result_cache=False,
            prefix_cache=False), stub_oracle=PETOracle(pet, seed=1))
        spec_rows = MIXED.expand()
        assert len(eng.machines) == len(spec_rows) == 4
        for m, s in zip(eng.machines, spec_rows):
            assert (m.mtype, m.speed, m.cost_rate, m.queue_size) == \
                (s.mtype, s.speed, s.cost_rate, s.queue_size)
        # same mids/fields as the simulator's build by construction
        sim_machines = MIXED.build_machines()
        assert [(m.mid, m.mtype, m.speed) for m in eng.machines] == \
            [(m.mid, m.mtype, m.speed) for m in sim_machines]


# ---------------------------------------------------------------------------
# per-unit backend dispatch
# ---------------------------------------------------------------------------

class TestBackendDispatch:
    def test_stub_backend_rows_need_no_jax_in_live_mode(self):
        """A live engine whose fleet rows are all ``backend=stub`` builds
        remote-endpoint stand-ins (no JAX, no model): durations come from
        the TimeEstimator oracle's ``sample`` and cost is accounted."""
        fleet = FleetSpec.parse("remote:2:1.0:0.1:stub")
        eng = ServingEngine(None, None, EngineConfig(
            fleet=fleet, elasticity=None, result_cache=False,
            prefix_cache=False, merging="none"))
        assert [u.kind for u in eng.units] == ["stub", "stub"]
        stats = eng.run(_request_trace(n=12, seed=0, deadline=1e9))
        assert stats["completed"] == 12
        assert stats["executions"] > 0
        assert stats["cost"] > 0.0
        # busy time can never exceed pool residency: at rate 0.1/tick the
        # execution cost is bounded by 0.1 x the machine-seconds integral
        assert stats["cost"] <= 0.1 * stats["machine_seconds"] + 1e-9
        assert stats["pool_cost"] == pytest.approx(
            0.1 * stats["machine_seconds"])

    def test_stub_backend_results_barred_from_result_cache(self):
        """Stub-backed units return no token payload; a repeat of the same
        request must re-execute, never be served an empty cached answer."""
        fleet = FleetSpec.parse("remote:1:1.0:0.1:stub")
        eng = ServingEngine(None, None, EngineConfig(
            fleet=fleet, elasticity=None, result_cache=True,
            prefix_cache=False, merging="none"))
        r1 = Request(prompt=(1, 2, 3, 4), n_new=2, deadline=1e9)
        eng.run([(0.0, r1)])
        r2 = Request(prompt=(1, 2, 3, 4), n_new=2, deadline=1e9)
        eng.run([(eng.clock, r2)])
        assert eng.stats["cache_hits"] == 0
        assert eng.stats["executions"] == 2

    def test_stub_engine_mode_overrides_backends(self):
        """stub_oracle engines are stub end-to-end regardless of catalog
        backends (the pre-fleet stub-execution mode, unchanged)."""
        pet = _pet(mtypes=("fast", "slow"))
        eng = ServingEngine(None, None, EngineConfig(
            fleet=MIXED, elasticity=None, result_cache=False,
            prefix_cache=False), stub_oracle=PETOracle(pet, seed=1))
        assert all(u.kind == "stub" for u in eng.units)


# ---------------------------------------------------------------------------
# cost-aware mapping heuristics
# ---------------------------------------------------------------------------

class _FixedOracle:
    """Deterministic oracle: mu ticks scaled by machine speed only."""

    def __init__(self, mu=10.0):
        self.mu = mu

    def mean_std(self, task, machine):
        return self.mu / machine.speed, 0.0


def _mk_task(deadline=1e6, **kw):
    kw.setdefault("ttype", "generate")
    kw.setdefault("data_id", "d")
    kw.setdefault("op", "generate")
    return Task(deadline=deadline, **kw)


class TestCostAwareHeuristics:
    def test_registered_like_the_rest(self):
        assert {"MEC", "MCMD"} <= set(HEURISTICS)
        assert make_heuristic("mec").name == "MEC"
        assert make_heuristic("MCMD").name == "MCMD"

    def test_mec_picks_cheapest_execution(self):
        fast = Machine(mid=0, cost_rate=1.0)
        cheap = Machine(mid=1, cost_rate=0.25)
        ctx = MappingContext(oracle=_FixedOracle())
        task = _mk_task()
        mapped = make_heuristic("MEC").map_batch([task], [fast, cheap], ctx)
        assert mapped == [(task, cheap)]
        assert ctx.exec_cost(task, cheap) < ctx.exec_cost(task, fast)

    def test_mec_cost_normalizes_speed(self):
        """A slow machine whose rate drops faster than its speed still
        wins: 10/0.5 ticks x 0.25 = 5 < 10 x 1.0."""
        fast = Machine(mid=0, speed=1.0, cost_rate=1.0)
        slow = Machine(mid=1, speed=0.5, cost_rate=0.25)
        ctx = MappingContext(oracle=_FixedOracle())
        task = _mk_task()
        assert make_heuristic("MEC").map_batch(
            [task], [fast, slow], ctx) == [(task, slow)]

    def test_mcmd_prefers_cheap_when_deadline_allows(self):
        fast = Machine(mid=0, speed=1.0, cost_rate=1.0)
        slow = Machine(mid=1, speed=0.5, cost_rate=0.25)
        ctx = MappingContext(oracle=_FixedOracle())        # 10 vs 20 ticks
        task = _mk_task(deadline=100.0)
        assert make_heuristic("MCMD").map_batch(
            [task], [fast, slow], ctx) == [(task, slow)]

    def test_mcmd_pays_for_speed_when_deadline_requires(self):
        fast = Machine(mid=0, speed=1.0, cost_rate=1.0)
        slow = Machine(mid=1, speed=0.5, cost_rate=0.25)
        ctx = MappingContext(oracle=_FixedOracle())
        task = _mk_task(deadline=15.0)      # 10 <= 15 < 20: only fast fits
        assert make_heuristic("MCMD").map_batch(
            [task], [fast, slow], ctx) == [(task, fast)]

    def test_mcmd_falls_back_to_earliest_completion(self):
        """No machine meets the deadline: QoS degrades before cost — the
        earliest completion wins, not the cheapest."""
        fast = Machine(mid=0, speed=1.0, cost_rate=1.0)
        slow = Machine(mid=1, speed=0.5, cost_rate=0.25)
        ctx = MappingContext(oracle=_FixedOracle())
        task = _mk_task(deadline=5.0)       # hopeless on both
        assert make_heuristic("MCMD").map_batch(
            [task], [fast, slow], ctx) == [(task, fast)]

    def test_mcmd_accounts_queue_buildup(self):
        """Greedy assignment sees its own queue: once the cheap machine's
        expected completion slips past the deadline, overflow goes to the
        fast one."""
        fast = Machine(mid=0, speed=1.0, cost_rate=1.0, queue_size=8)
        slow = Machine(mid=1, speed=0.5, cost_rate=0.25, queue_size=8)
        ctx = MappingContext(oracle=_FixedOracle())
        tasks = [_mk_task(data_id=f"d{i}", deadline=45.0) for i in range(4)]
        mapped = dict(
            (t.data_id, m.mid)
            for t, m in make_heuristic("MCMD").map_batch(
                tasks, [fast, slow], ctx))
        # 20-tick jobs on slow: two fit under 45; the rest must go fast
        assert [mapped[f"d{i}"] for i in range(4)] == [1, 1, 0, 0]


# ---------------------------------------------------------------------------
# cheapest-first scale-up / priciest-first retirement + cost integrals
# ---------------------------------------------------------------------------

class TestFleetElasticity:
    def test_sim_grows_cheapest_fleet_row(self):
        pet = _pet(mtypes=("fast", "slow"), inconsistent=False)
        fleet = FleetSpec.parse("fast:1:1.0:1.0,slow:1:0.5:0.25")
        tasks = _mirror_tasks(_request_trace(n=60, seed=2, rate=2.0,
                                             deadline=1e6))
        sim = Simulator(tasks, fleet, PETOracle(pet, seed=3),
                        SimConfig(heuristic="EDF", merging="none",
                                  elasticity=ElasticityConfig(
                                      policy="queue", max_extra=2,
                                      scale_up_queue=6, scale_down_queue=1)))
        st = sim.run()
        assert st.scale_ups > 0
        # every scaler-added machine is the cheapest catalog row
        extras = [m for m in sim.machines if m.mid > 2]
        assert all(m.mtype == "slow" and m.cost_rate == 0.25
                   for m in extras)
        # per-mtype billing: extras bill at 0.25, never the homogeneous 1.0
        assert st.extra_pool_cost == pytest.approx(
            0.25 * st.extra_machine_seconds)

    def test_engine_retires_priciest_idle_unit(self):
        pet = _pet(mtypes=("exp", "cheap"), inconsistent=False)
        fleet = FleetSpec.parse("cheap:1:1.0:0.1,exp:1:1.0:1.0,"
                                "cheap:1:1.0:0.1")
        eng = ServingEngine(None, None, EngineConfig(
            fleet=fleet, elasticity=None, result_cache=False,
            prefix_cache=False), stub_oracle=PETOracle(pet, seed=1))
        pool = _EngineUnitPool(eng)
        assert pool.cost_rate() == pytest.approx(1.2)
        assert pool.shrink(0.0)             # all idle: priciest goes first
        assert [u.machine.mtype for u in eng.units] == ["cheap", "cheap"]

    def test_fixed_pool_cost_is_rate_times_makespan(self):
        pet = _pet(mtypes=("fast", "slow"), inconsistent=False)
        sim = Simulator(
            _mirror_tasks(_request_trace(n=10, seed=0, deadline=1e6)),
            MIXED, PETOracle(pet, seed=1), SimConfig())
        st = sim.run()
        assert st.pool_cost == pytest.approx(
            MIXED.cost_rate_total() * st.makespan)
        assert st.pool_cost < st.machine_seconds   # cheap rows bill < 1.0

    def test_plane_pool_bills_base_fleet_not_unit_churn(self):
        """The Router's plane scaler bills each live plane at its *base*
        fleet rate: a plane's own unit-level scaler already accounts its
        extra units, so unit churn must not leak into the plane budget."""
        from repro.serving.cluster import Plane, Router, _PlanePool
        pet = _pet()
        fleet = FleetSpec.parse("m0:2:1.0:0.5")
        eng = ServingEngine(None, None, EngineConfig(
            fleet=fleet, elasticity=None, result_cache=False,
            prefix_cache=False), stub_oracle=PETOracle(pet, seed=1))
        router = Router([Plane(eng, pid=0)])
        pool = _PlanePool(router, factory=lambda pid: None)
        assert pool.cost_rate() == pytest.approx(1.0)
        eng._add_unit()                     # unit-level growth
        assert len(eng.units) == 3
        assert pool.cost_rate() == pytest.approx(1.0)   # unchanged

    def test_cost_budget_gates_scale_up(self):
        cfg = ElasticityConfig(policy="cost-aware", budget_cost=50.0,
                               pressure_lam=1.0, pressure_on=1.0)
        pol = CostAwareScaler(cfg)
        risky = np.zeros(8)
        sig_in = ScaleSignals(0.0, 8, chances_fn=lambda: risky,
                              extra_cost=0.0)
        assert pol.decide(sig_in) == 1              # in budget
        sig_out = ScaleSignals(0.0, 8, chances_fn=lambda: risky,
                               extra_cost=50.0)
        assert pol.decide(sig_out) == -1            # burned: drain


# ---------------------------------------------------------------------------
# per-machine KV caches in the simulator
# ---------------------------------------------------------------------------

class TestPerMachineKVCaches:
    def _prefix_tasks(self, n=10, gap=40.0):
        sys_prompt = tuple(range(1, 33))
        rng = np.random.default_rng(0)
        out = []
        for i in range(n):
            toks = sys_prompt + tuple(rng.integers(100, 200,
                                                   size=8).tolist())
            out.append(Task(ttype="generate", data_id=f"d{i}",
                            op="generate", arrival=i * gap,
                            deadline=i * gap + 500.0, tokens=toks))
        return out

    def test_hits_attributed_to_the_caching_machine(self):
        """Shared-prefix tasks follow the blocks: after the first
        execution caches the prefix on one machine, the per-unit locality
        term steers every later task there — hits land on that machine's
        cache and nowhere else."""
        pet = _pet(seed=1, mean_range=(15, 25))
        sim = Simulator(self._prefix_tasks(), FleetSpec.homogeneous(2),
                        PETOracle(pet, seed=3),
                        SimConfig(heuristic="EDF", prefix_cache_blocks=64,
                                  kv_block_size=16, kv_per_machine=True))
        st = sim.run()
        assert st.prefix_hits == 9              # all but the cold first
        per_cache = sorted(c.stats["hits"] for c in sim.kvcaches.values())
        assert per_cache == [0, 9]              # one owner, zero strays
        assert st.on_time == 10

    def test_locality_term_discriminates_between_machines(self):
        pet = _pet(seed=1)
        sim = Simulator([], FleetSpec.homogeneous(2), PETOracle(pet, seed=3),
                        SimConfig(heuristic="EDF", prefix_cache_blocks=64,
                                  kv_block_size=16, kv_per_machine=True))
        toks = tuple(range(1, 33))
        m1, m2 = sim.machines
        sim.kvcaches[m1.mid].insert(toks)
        probe = Task(ttype="generate", data_id="p", op="generate",
                     tokens=toks + (99, 98))
        assert sim._prefix_locality(probe, m1) == 32
        assert sim._prefix_locality(probe, m2) == 0
        # the engine-wide PREFIX admission score is the best across units
        assert sim.detector.find_prefix_overlap(probe.tokens) == 32

    def test_shared_mode_unchanged_by_default(self):
        pet = _pet(seed=1)
        sim = Simulator([], FleetSpec.homogeneous(2), PETOracle(pet, seed=3),
                        SimConfig(prefix_cache_blocks=16))
        assert sim.kvcache is not None and not sim.kvcaches
        assert not sim.cfg.kv_per_machine


# ---------------------------------------------------------------------------
# the Eq. 4.3 OSL pressure signal
# ---------------------------------------------------------------------------

class TestOSLPressureSignal:
    def test_signal_default_is_zero(self):
        assert ScaleSignals(0.0, 3).osl() == 0.0

    def test_success_chance_policy_reads_osl_when_selected(self):
        cfg = ElasticityConfig(policy="success-chance",
                               pressure_signal="osl", osl_up=0.25,
                               osl_down=0.05, scale_down_queue=2)
        pol = SuccessChanceScaler(cfg)
        hot = ScaleSignals(0.0, 6, osl_fn=lambda: 0.9)
        cool = ScaleSignals(0.0, 1, osl_fn=lambda: 0.0)
        mid = ScaleSignals(0.0, 6, osl_fn=lambda: 0.1)
        assert pol.decide(hot) == 1
        assert pol.decide(cool) == -1
        assert pol.decide(mid) == 0
        # selecting OSL must never pay for the chance convolution
        boom = ScaleSignals(0.0, 6, chances_fn=lambda: 1 / 0,
                            osl_fn=lambda: 0.9)
        assert pol.decide(boom) == 1

    def test_chance_default_ignores_osl(self):
        cfg = ElasticityConfig(policy="success-chance")
        pol = SuccessChanceScaler(cfg)
        sig = ScaleSignals(0.0, 6, chances_fn=lambda: np.full(6, 0.2),
                           osl_fn=lambda: 1 / 0)
        assert pol.decide(sig) == 1         # low chance, OSL never touched

    def test_cost_aware_osl_pressure_through_schmitt(self):
        cfg = ElasticityConfig(policy="cost-aware", pressure_signal="osl",
                               pressure_lam=1.0, pressure_on=0.3,
                               scale_down_queue=0)
        pol = CostAwareScaler(cfg)
        assert pol.decide(ScaleSignals(0.0, 4, osl_fn=lambda: 0.5)) == 1
        assert pol.decide(ScaleSignals(0.0, 4, osl_fn=lambda: 0.0)) == 0

    def test_end_to_end_scaling_on_both_substrates(self):
        """An overloaded pool under OSL pressure scales up on the engine
        and the simulator alike (substrate-independent wiring)."""
        pet = _pet(seed=3, mean_range=(8, 16))
        el = ElasticityConfig(policy="success-chance",
                              pressure_signal="osl", max_extra=2,
                              cooldown=10.0, osl_up=0.1, osl_down=0.01)
        trace = _request_trace(n=40, seed=1, deadline=60.0, rate=1.0)
        eng = ServingEngine(None, None, EngineConfig(
            n_units=1, heuristic="EDF", merging="none", result_cache=False,
            prefix_cache=False, elasticity=el),
            stub_oracle=PETOracle(pet, seed=11))
        stats = eng.run(trace)
        sim = Simulator(_mirror_tasks(trace), FleetSpec.homogeneous(1),
                        PETOracle(pet, seed=11),
                        SimConfig(heuristic="EDF", merging="none",
                                  elasticity=el))
        st = sim.run()
        assert stats["scale_ups"] > 0 and st.scale_ups > 0
        assert stats["machine_seconds"] > 0 and st.machine_seconds > 0
