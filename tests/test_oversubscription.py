"""Coverage for core/oversubscription.py: the OSL -> adaptive-alpha map
(Eq. 4.3 / §4.5.3) and the Eq. 5.11 EWMA + Schmitt-trigger DropToggle that
both the pruner and the cost-aware autoscaler build on."""

import pytest

from repro.core.oversubscription import (DropToggle, adaptive_alpha,
                                         oversubscription_level)
from repro.core.tasks import Machine, Task


def _task(deadline, arrival=0.0):
    return Task(ttype="t0", data_id="d", op="op", arrival=arrival,
                deadline=deadline)


class TestDropToggle:
    def test_engages_at_on_level_and_holds_through_noise(self):
        """A noisy miss sequence oscillating across the on-level (but above
        the off-level) must produce exactly one engage transition — no
        chatter (Section 5.3.5's 20% separation is the point)."""
        tg = DropToggle(lam=0.5, on_level=2.0)
        assert tg.off_level == pytest.approx(1.6)
        states = [tg.observe(m) for m in (3, 3, 1, 3, 1, 3, 1, 3)]
        # d: 1.5, 2.25*, 1.625, 2.3125, 1.656, 2.328, 1.664, 2.332 — the
        # dips stay above off_level, so once engaged it stays engaged
        assert states[0] is False
        assert all(states[1:])
        transitions = sum(1 for a, b in zip([False] + states, states)
                          if a != b)
        assert transitions == 1

    def test_without_schmitt_the_same_sequence_chatters(self):
        tg = DropToggle(lam=0.5, on_level=2.0, use_schmitt=False)
        states = [tg.observe(m) for m in (3, 3, 1, 3, 1, 3, 1, 3)]
        transitions = sum(1 for a, b in zip([False] + states, states)
                          if a != b)
        assert transitions > 2   # naive threshold flips on every dip

    def test_disengages_only_at_off_level(self):
        tg = DropToggle(lam=0.5, on_level=2.0)
        tg.observe(10)                       # d = 5.0 -> engaged
        assert tg.engaged
        while tg.d > tg.off_level:
            tg.observe(0)
            if tg.d > tg.off_level:
                assert tg.engaged            # still above: must hold
        assert not tg.engaged                # crossed off_level: released

    def test_ewma_matches_eq_5_11(self):
        tg = DropToggle(lam=0.3, on_level=100.0)
        d = 0.0
        for m in (4, 0, 7, 2, 0, 0, 9):
            tg.observe(m)
            d = m * 0.3 + d * 0.7
            assert tg.d == pytest.approx(d)
        assert len(tg.history) == 7
        assert tg.history[-1] == pytest.approx(d)


class TestAdaptiveAlpha:
    @pytest.mark.parametrize("osl,alpha", [
        (0.0, 2.0),          # no oversubscription: conservative 2-sigma
        (0.25, 1.0),
        (0.5, 0.0),
        (1.0, -2.0),         # fully oversubscribed: aggressive
    ])
    def test_linear_map(self, osl, alpha):
        assert adaptive_alpha(osl) == pytest.approx(alpha)

    @pytest.mark.parametrize("osl", [1.5, 4.0, 100.0, 1e9])
    def test_clamped_at_extreme_oversubscription(self, osl):
        assert adaptive_alpha(osl) == -2.0

    @pytest.mark.parametrize("osl", [-0.1, -5.0])
    def test_clamped_below(self, osl):
        assert adaptive_alpha(osl) == 2.0


class TestOversubscriptionLevel:
    def exec_time(self, mu, sd=0.0):
        return lambda task, machine: (mu, sd)

    def test_empty_queues_zero(self):
        m = Machine(mid=0)
        assert oversubscription_level([m], self.exec_time(10.0), 0.0) == 0.0

    def test_on_time_tasks_contribute_zero(self):
        m = Machine(mid=0)
        m.queue = [_task(100.0), _task(120.0)]
        assert oversubscription_level([m], self.exec_time(10.0), 0.0) == 0.0

    def test_infeasible_tasks_contribute_zero(self):
        # W = deadline - arrival - e < 0: the request was never servable,
        # so it cannot count as oversubscription pressure
        m = Machine(mid=0)
        m.queue = [_task(5.0)]
        assert oversubscription_level([m], self.exec_time(10.0), 0.0) == 0.0

    def test_severity_capped_at_four(self):
        # e=10, deadline=11 -> W=1; completion ~10k -> ratio huge, capped
        m = Machine(mid=0)
        m.queue = [_task(11.0, arrival=0.0)]
        m.running = _task(1e6)
        m.run_end = 1e4
        osl = oversubscription_level([m], self.exec_time(10.0), 0.0)
        assert osl == pytest.approx(4.0)
        assert adaptive_alpha(osl) == -2.0

    def test_alpha_widens_estimates(self):
        # alpha enters e = mu + alpha*sigma: a fat-sigma estimate can turn
        # an on-time queue oversubscribed
        m = Machine(mid=0)
        m.queue = [_task(30.0), _task(32.0)]
        assert oversubscription_level(
            [m], self.exec_time(10.0, sd=1.0), 0.0, alpha=2.0) == 0.0
        osl = oversubscription_level(
            [m], self.exec_time(14.0, sd=4.0), 0.0, alpha=2.0)
        assert osl > 0.0
