"""Per-kernel validation: shape/dtype sweeps + hypothesis property tests,
all against the pure-jnp ref oracles, executed with interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # minimal install: keep unit tests, skip property tests
    from conftest import given, settings, st  # noqa: F401

from repro.core.pmf import PMF, chance_of_success
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.pmf_conv.ops import batched_success, pmf_conv
from repro.kernels.pmf_conv.ref import pmf_conv_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# pmf_conv
# ---------------------------------------------------------------------------

class TestPmfConv:
    def _data(self, n, le, lc, seed=0):
        rng = np.random.default_rng(seed)
        pet = rng.random((n, le)).astype(np.float32)
        pet /= pet.sum(axis=1, keepdims=True)
        pct = rng.random((n, lc)).astype(np.float32)
        pct /= pct.sum(axis=1, keepdims=True)
        dl = rng.integers(0, le + lc, size=n).astype(np.float32)
        return jnp.asarray(pet), jnp.asarray(pct), jnp.asarray(dl)

    @pytest.mark.parametrize("n,le,lc", [(4, 8, 16), (16, 32, 32),
                                         (3, 5, 64), (9, 64, 128)])
    def test_matches_ref(self, n, le, lc):
        pet, pct, dl = self._data(n, le, lc)
        out_k, suc_k = pmf_conv(pet, pct, dl, use_kernel=True)
        out_r, suc_r = pmf_conv_ref(pet, pct, dl)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-6, rtol=1e-5)
        np.testing.assert_allclose(np.asarray(suc_k), np.asarray(suc_r),
                                   atol=1e-6, rtol=1e-5)

    def test_mass_conservation(self):
        pet, pct, dl = self._data(8, 16, 24, seed=3)
        out, _ = pmf_conv(pet, pct, dl)
        np.testing.assert_allclose(np.asarray(out).sum(axis=1), 1.0,
                                   atol=1e-5)

    def test_success_against_core_pmf(self):
        """End-to-end: kernel success == core.pmf.chance_of_success."""
        rng = np.random.default_rng(7)
        pets, pcts, dls = [], [], []
        for _ in range(12):
            e = PMF.from_normal(rng.uniform(8, 30), rng.uniform(1, 5))
            c = PMF.from_normal(rng.uniform(10, 60), rng.uniform(2, 8))
            pets.append(e)
            pcts.append(c)
            dls.append(int(e.mean() + c.mean() + rng.integers(-10, 15)))
        got = batched_success(pets, pcts, dls, length=128)
        want = [chance_of_success(e, c, dl, droppable_prev=True)
                for e, c, dl in zip(pets, pcts, dls)]
        np.testing.assert_allclose(got, want, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 12), st.integers(2, 24), st.integers(2, 48),
           st.integers(0, 10_000))
    def test_prop_kernel_equals_ref(self, n, le, lc, seed):
        pet, pct, dl = self._data(n, le, lc, seed=seed)
        out_k, suc_k = pmf_conv(pet, pct, dl, use_kernel=True)
        out_r, suc_r = pmf_conv_ref(pet, pct, dl)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                                   atol=1e-6, rtol=1e-4)
        np.testing.assert_allclose(np.asarray(suc_k), np.asarray(suc_r),
                                   atol=1e-6)


# ---------------------------------------------------------------------------
# decode_attention
# ---------------------------------------------------------------------------

class TestDecodeAttention:
    def _data(self, b, s, h, hkv, hd, dtype, seed=0):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        q = jax.random.normal(ks[0], (b, h, hd), dtype)
        k = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
        v = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
        lengths = jax.random.randint(ks[3], (b,), 1, s + 1)
        return q, k, v, lengths

    @pytest.mark.parametrize("b,s,h,hkv,hd,bs", [
        (2, 128, 8, 4, 32, 64), (1, 256, 4, 1, 64, 128),
        (3, 96, 6, 2, 16, 32), (2, 512, 16, 16, 64, 512),
    ])
    def test_matches_ref_shapes(self, b, s, h, hkv, hd, bs):
        q, k, v, lengths = self._data(b, s, h, hkv, hd, jnp.float32)
        out = decode_attention(q, k, v, lengths, block_s=bs)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        q, k, v, lengths = self._data(2, 64, 4, 2, 32, dtype)
        out = decode_attention(q, k, v, lengths, block_s=32)
        ref = decode_attention_ref(q, k, v, lengths)
        tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=tol, rtol=tol)

    def test_masking_exact(self):
        """Entries beyond `length` must not affect the output at all."""
        q, k, v, lengths = self._data(2, 64, 4, 2, 32, jnp.float32)
        lengths = jnp.array([10, 30])
        out1 = decode_attention(q, k, v, lengths, block_s=16)
        k2 = k.at[:, 40:].set(99.0)
        v2 = v.at[:, 40:].set(-99.0)
        out2 = decode_attention(q, k2, v2, lengths, block_s=16)
        np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 3), st.sampled_from([32, 48, 96]),
           st.sampled_from([(4, 2), (4, 4), (8, 2)]),
           st.integers(0, 10_000))
    def test_prop_kernel_equals_ref(self, b, s, heads, seed):
        h, hkv = heads
        q, k, v, lengths = self._data(b, s, h, hkv, 16, jnp.float32, seed)
        out = decode_attention(q, k, v, lengths, block_s=32)
        ref = decode_attention_ref(q, k, v, lengths)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

class TestRmsnorm:
    @pytest.mark.parametrize("shape,dtype", [
        ((4, 128), jnp.float32), ((2, 16, 256), jnp.bfloat16),
        ((1, 960), jnp.float32), ((5, 7, 64), jnp.bfloat16),
    ])
    def test_matches_ref(self, shape, dtype):
        x = jax.random.normal(KEY, shape, dtype)
        scale = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], dtype)
        out = rmsnorm(x, scale)
        ref = rmsnorm_ref(x, scale)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32),
                                   atol=2e-2, rtol=2e-2)

    def test_unit_variance(self):
        x = 37.0 * jax.random.normal(KEY, (8, 512), jnp.float32)
        out = rmsnorm(x, jnp.ones((512,)))
        rms = np.asarray(jnp.sqrt(jnp.mean(out * out, axis=-1)))
        np.testing.assert_allclose(rms, 1.0, atol=1e-3)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 64), st.sampled_from([64, 128, 384]),
           st.integers(0, 10_000))
    def test_prop_kernel_equals_ref(self, rows, d, seed):
        x = jax.random.normal(jax.random.PRNGKey(seed), (rows, d),
                              jnp.float32)
        scale = jnp.ones((d,))
        np.testing.assert_allclose(np.asarray(rmsnorm(x, scale)),
                                   np.asarray(rmsnorm_ref(x, scale)),
                                   atol=1e-5, rtol=1e-5)
