"""Prefill/decode disaggregation (DESIGN.md §2.13): KV block migration,
phase-specialized planes, handoff scheduling, and the retire-migrates-blocks
regression.

Layers under test:
  * ``serving.kvcache.migrate`` — trie-to-trie block movement preserving
    structure, attribution and refcounts, priced by TransferCostModel;
  * ``core.heuristics.pick_handoff_machine`` — migration cost weighed
    against locality and expected completion;
  * both substrates end to end — stub-engine ↔ simulator decision-trace
    equivalence with disaggregation ON, and bitwise greedy token identity
    across a live-engine prefill→decode handoff;
  * pool retirement — a retiring unit's cached blocks migrate to a
    survivor instead of being dropped (the pre-§2.13 gap).
"""

import numpy as np
import pytest

from repro.core.fleet import FleetSpec, MachineSpec, kv_block_budget
from repro.core.heuristics import MappingContext, pick_handoff_machine
from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.simulation import _SimMachinePool
from repro.core.tasks import Machine, PETMatrix, Task
from repro.obs import Telemetry, validate_chrome_trace
from repro.obs.exporters import chrome_trace
from repro.serving.batching import StepBatchingConfig
from repro.serving.engine import EngineConfig, Request, ServingEngine
from repro.serving.kvcache import (PrefixKVCache, TransferCostModel,
                                   migrate, migration_cost)


def _toks(n, base=0):
    return tuple(range(base, base + n))


# ---------------------------------------------------------------------------
# migrate(): trie surgery + attribution + pricing
# ---------------------------------------------------------------------------

class TestMigrate:
    def test_whole_trie_moves_and_src_drains(self):
        src = PrefixKVCache(16, 4)
        dst = PrefixKVCache(16, 4)
        a, b = _toks(12), _toks(8) + _toks(4, base=100)
        src.insert(a)
        src.insert(b)          # shares the first 8-token run with ``a``
        res = migrate(src, dst)
        assert res.blocks == 4 and res.dropped == 0
        assert dst.index.match_len(a) == 12
        assert dst.index.match_len(b) == 12
        assert len(src.index) == 0
        assert src.pool.n_free == 16
        assert src.stats["migrated_out"] == 4
        assert dst.stats["migrated_in"] == 4

    def test_chain_migration_moves_only_the_prompt_path(self):
        src = PrefixKVCache(16, 4)
        dst = PrefixKVCache(16, 4)
        a, b = _toks(8), _toks(4, base=50)
        src.insert(a)
        src.insert(b)
        migrate(src, dst, a)
        assert dst.index.match_len(a) == 8
        assert dst.index.match_len(b) == 0      # unrelated chain stays put
        assert src.index.match_len(b) == 4

    def test_attribution_rides_along(self):
        src = PrefixKVCache(8, 4, clock_fn=lambda: 5.0)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(4))
        hit = src.lookup(_toks(5))              # hits += 1 on the block
        src.release(hit)
        migrate(src, dst, _toks(4), now=9.0)
        blk = dst.index.walk(_toks(4))[0].block
        assert blk.hits == 1
        assert blk.last_used == 9.0             # max(src last_used, now)

    def test_dedupe_merges_attribution_instead_of_copying(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(8))
        dst.insert(_toks(4))                    # first block already there
        src.lookup(_toks(8))                    # leave it pinned on src too
        res = migrate(src, dst, _toks(8), release_src=False)
        assert res.blocks == 1 and res.skipped == 1
        assert dst.index.walk(_toks(4))[0].block.hits == 1  # merged

    def test_pinned_src_blocks_are_copied_but_not_freed(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(8))
        hit = src.lookup(_toks(8))              # pin both blocks
        res = migrate(src, dst, _toks(8))
        assert res.blocks == 2
        assert dst.index.match_len(_toks(8)) == 8
        assert src.index.match_len(_toks(8)) == 8   # still readable on src
        src.release(hit)

    def test_dst_exhaustion_drops_the_tail_not_the_prefix(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(1, 4)
        src.insert(_toks(12))                   # 3 blocks, dst holds 1
        res = migrate(src, dst, _toks(12), release_src=False)
        assert res.blocks == 1 and res.dropped == 2
        assert dst.index.match_len(_toks(12)) == 4  # prefix property intact

    def test_block_size_mismatch_raises(self):
        with pytest.raises(ValueError):
            migrate(PrefixKVCache(4, 4), PrefixKVCache(4, 8))

    def test_cost_model_prices_by_slower_endpoint(self):
        m = TransferCostModel(base_cost=0.5, per_token=0.01)
        assert m.cost(0, 16) == 0.0
        assert m.cost(4, 16) == pytest.approx(0.5 + 64 * 0.01)
        assert m.cost(4, 16, src_speed=2.0, dst_speed=0.5) == \
            pytest.approx(0.5 + 64 * 0.01 / 0.5)

    def test_migration_cost_credits_resident_dst_prefix(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(12))
        dst.insert(_toks(4))
        m = TransferCostModel()
        full = migration_cost(src, PrefixKVCache(8, 4), _toks(12), m)
        partial = migration_cost(src, dst, _toks(12), m)
        assert partial < full

    def test_migrate_emits_telemetry(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(8))
        tel = Telemetry()
        migrate(src, dst, _toks(8), cost_model=TransferCostModel(),
                src_mid=1, dst_mid=2, tel=tel)
        (ev,) = tel.events_of("kv_migrate")
        assert ev["blocks"] == 2 and ev["src"] == 1 and ev["dst"] == 2
        assert ev["cost"] > 0
        snap = tel.metrics.snapshot()
        assert snap["counters"]["kv_migrations"] == 1
        assert snap["counters"]["kv_blocks_migrated"] == 2

    def test_kv_migrate_renders_as_perfetto_flow(self):
        src = PrefixKVCache(8, 4)
        dst = PrefixKVCache(8, 4)
        src.insert(_toks(8))
        tel = Telemetry()
        migrate(src, dst, _toks(8), src_mid=1, dst_mid=2, tel=tel)
        trace = chrome_trace(tel.events)
        validate_chrome_trace(trace)
        flows = [e for e in trace["traceEvents"] if e["ph"] in ("s", "f")]
        assert len(flows) == 2
        s, f = sorted(flows, key=lambda e: e["ph"], reverse=True)
        assert s["ph"] == "s" and s["tid"] == 1
        assert f["ph"] == "f" and f["tid"] == 2
        assert s["id"] == f["id"]


# ---------------------------------------------------------------------------
# admission-aware per-unit block budgets (satellite)
# ---------------------------------------------------------------------------

class TestKVBudget:
    def test_mixed_at_speed_one_is_identity(self):
        assert kv_block_budget(512) == 512

    def test_phase_and_speed_scale_the_pool(self):
        assert kv_block_budget(512, "prefill") == 256
        assert kv_block_budget(512, "decode") == 768
        assert kv_block_budget(512, "decode", speed=2.0) == 1536
        assert kv_block_budget(1, "prefill", speed=0.1) == 1  # floor

    def test_spec_kv_blocks(self):
        assert MachineSpec(phase="decode", speed=0.5).kv_blocks(512) == 384

    def test_fleet_phase_roundtrip_and_flags(self):
        fs = FleetSpec.parse("pre@prefill:1:1.5:1.25,dec@decode:2:0.5:0.35")
        assert fs.disaggregated
        assert [s.phase for s in fs.expand()] == \
            ["prefill", "decode", "decode"]
        assert FleetSpec.parse(fs.serialize()) == fs
        assert not FleetSpec.homogeneous(2).disaggregated

    def test_bad_phase_rejected(self):
        with pytest.raises(ValueError):
            MachineSpec(phase="verify")

    def test_sim_sizes_per_machine_caches_by_phase(self):
        fleet = FleetSpec.parse("p@prefill:1,d@decode:1")
        sim = Simulator(
            [], fleet,
            PETOracle(PETMatrix.generate(
                ["generate"], ["p", "d"], np.random.default_rng(0))),
            SimConfig(prefix_cache_blocks=64, kv_per_machine=True))
        sizes = {m.phase: sim.kvcaches[m.mid].pool.n_blocks
                 for m in sim.machines}
        assert sizes == {"prefill": 32, "decode": 96}


# ---------------------------------------------------------------------------
# handoff destination scoring: migration cost vs locality vs completion
# ---------------------------------------------------------------------------

def _pet(mtypes, seed=3):
    rng = np.random.default_rng(seed)
    return PETMatrix.generate(["generate"], mtypes, rng, mean_range=(8, 16))


class TestHandoffScoring:
    def _ctx(self, mtypes=("m0",)):
        return MappingContext(oracle=PETOracle(_pet(list(mtypes))), now=0.0)

    def test_prefill_machines_are_not_candidates(self):
        src = Machine(mid=1, phase="prefill")
        other_pre = Machine(mid=2, phase="prefill")
        dec = Machine(mid=3, phase="decode")
        task = Task(ttype="generate", data_id="d", op="generate")
        got = pick_handoff_machine(task, src, [src, other_pre, dec],
                                   self._ctx())
        assert got is dec

    def test_no_decode_capable_machine_returns_none(self):
        src = Machine(mid=1, phase="prefill")
        task = Task(ttype="generate", data_id="d", op="generate")
        assert pick_handoff_machine(task, src, [src], self._ctx()) is None

    def test_migration_cost_steers_toward_resident_prefix(self):
        """Identical decode machines; the migrate-cost model says machine 3
        already holds the prefix (cost 0) — locality must win."""
        src = Machine(mid=1, phase="prefill")
        d2 = Machine(mid=2, phase="decode")
        d3 = Machine(mid=3, phase="decode")
        task = Task(ttype="generate", data_id="d", op="generate",
                    deadline=1e9)
        costs = {2: 5.0, 3: 0.0}
        got = pick_handoff_machine(
            task, src, [src, d2, d3], self._ctx(),
            migrate_cost_fn=lambda t, s, m: costs[m.mid])
        assert got is d3

    def test_feasible_cheap_machine_beats_fast_expensive(self):
        """Both feasible: MCMD semantics — exec cost (plus migration)
        decides, not raw completion."""
        src = Machine(mid=1, phase="prefill", mtype="m0")
        cheap = Machine(mid=2, phase="decode", mtype="m0", cost_rate=0.2)
        fast = Machine(mid=3, phase="decode", mtype="m0", speed=4.0,
                       cost_rate=2.0)
        task = Task(ttype="generate", data_id="d", op="generate",
                    deadline=1e9)
        got = pick_handoff_machine(task, src, [src, cheap, fast],
                                   self._ctx())
        assert got is cheap

    def test_infeasible_falls_back_to_earliest_completion(self):
        src = Machine(mid=1, phase="prefill", mtype="m0")
        slow = Machine(mid=2, phase="decode", mtype="m0", speed=0.1,
                       cost_rate=0.01)
        fast = Machine(mid=3, phase="decode", mtype="m0", speed=4.0,
                       cost_rate=9.0)
        task = Task(ttype="generate", data_id="d", op="generate",
                    deadline=0.001)          # nobody makes it
        got = pick_handoff_machine(task, src, [src, slow, fast],
                                   self._ctx())
        assert got is fast


# ---------------------------------------------------------------------------
# substrate equivalence with disaggregation ON
# ---------------------------------------------------------------------------

def _request_trace(n=40, seed=1, n_prompts=5, deadline=80.0, rate=0.5):
    rng = np.random.default_rng(seed)
    # prompts longer than one KV block (16 tokens) so handoffs carry a
    # non-zero modeled transfer cost
    prompts = [tuple(rng.integers(1, 1000, size=48).tolist())
               for _ in range(n_prompts)]
    out, t = [], 0.0
    for _ in range(n):
        out.append((t, Request(
            prompt=prompts[int(rng.integers(0, n_prompts))], op="generate",
            n_new=int(rng.integers(1, 4)), seed=int(rng.integers(0, 2)),
            deadline=t + deadline)))
        t += float(rng.exponential(1.0 / rate))
    return out


def _mirror_tasks(trace):
    return [Task(ttype=req.op, data_id=str(hash(req.prompt)), op=req.op,
                 params=req.params_sig, arrival=t, deadline=req.deadline,
                 user=f"u{i % 8}", tokens=req.prompt)
            for i, (t, req) in enumerate(trace)]


class TestDisaggTraceEquivalence:
    @pytest.mark.parametrize("heuristic", ["EDF", "MCMD"])
    def test_same_trace_same_decisions_disaggregated(self, heuristic):
        """The §2.13 acceptance gate: with phase roles declared, handoff
        events (destination pick + modeled migration cost) land bit-equal
        on both analytic substrates."""
        pet = _pet(["pre", "dec"])
        trace = _request_trace()
        fleet = FleetSpec.parse("pre@prefill:1,dec@decode:1")
        bat = StepBatchingConfig(max_batch=4, step_token_budget=32)
        kw = dict(heuristic=heuristic, merging="adaptive", pruning=None)

        eng = ServingEngine(None, None, EngineConfig(
            fleet=fleet, elasticity=None, result_cache=False,
            prefix_cache=False, batching=bat, **kw),
            stub_oracle=PETOracle(pet, seed=11))
        eng.cp.trace = []
        stats = eng.run(trace)

        sim = Simulator(_mirror_tasks(trace), fleet,
                        PETOracle(pet, seed=11), SimConfig(batching=bat, **kw))
        sim.cp.trace = []
        st = sim.run()

        assert sim.cp.trace == eng.cp.trace
        hand = [e for e in sim.cp.trace if e[0] == "handoff"]
        assert hand, "disaggregated fleet must hand sequences off"
        for _, idx, dst, cost in hand:
            assert dst == 1          # the one decode machine (index 1)
            assert cost > 0          # priced by the shared transfer model
        assert (st.on_time, st.missed, st.dropped) == \
            (stats["on_time"], stats["missed"], stats["dropped"])
        assert st.cost == pytest.approx(stats["cost"], abs=1e-9)

    def test_unified_fleet_traces_unchanged(self):
        """mixed-phase fleets must take the exact pre-§2.13 code path: no
        handoff events, traces identical to a FleetSpec.homogeneous run."""
        pet = _pet(["m0"])
        trace = _request_trace(n=25)
        bat = StepBatchingConfig(max_batch=4, step_token_budget=32)

        def run(fleet):
            sim = Simulator(_mirror_tasks(trace), fleet,
                            PETOracle(pet, seed=11),
                            SimConfig(batching=bat, merging="adaptive"))
            sim.cp.trace = []
            sim.run()
            return sim.cp.trace

        a = run(FleetSpec.homogeneous(2))
        b = run(FleetSpec.parse("m0:2"))
        assert a == b
        assert not any(e[0] == "handoff" for e in a)


# ---------------------------------------------------------------------------
# retirement migrates blocks (regression — both pool adapters)
# ---------------------------------------------------------------------------

class TestRetireMigratesBlocks:
    def test_sim_pool_shrink_rescues_cached_prefixes(self):
        fleet = FleetSpec.homogeneous(1)
        sim = Simulator(
            [], fleet,
            PETOracle(PETMatrix.generate(
                ["generate"], ["m0"], np.random.default_rng(0))),
            SimConfig(prefix_cache_blocks=32, kv_per_machine=True))
        pool = _SimMachinePool(sim)
        pool.grow(0.0)
        extra = sim.machines[-1]
        toks = _toks(64)
        sim.kvcaches[extra.mid].insert(toks)
        base_mid = sim.machines[0].mid
        assert sim.kvcaches[base_mid].peek(toks) == 0
        assert pool.shrink(1.0)
        # pre-§2.13 this was dropped on the floor; now the survivor serves
        # the prefix
        assert sim.kvcaches[base_mid].peek(toks) == 64
        assert extra.mid not in sim.kvcaches

    def test_sim_pool_shrink_without_survivor_caches_still_works(self):
        sim = Simulator(
            [], FleetSpec.homogeneous(1),
            PETOracle(PETMatrix.generate(
                ["generate"], ["m0"], np.random.default_rng(0))),
            SimConfig())
        pool = _SimMachinePool(sim)
        pool.grow(0.0)
        assert pool.shrink(1.0)     # no kvcaches at all: plain retire


# ---------------------------------------------------------------------------
# live engine: bitwise token identity across the prefill→decode handoff
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_model():
    import jax
    from repro.configs.registry import ARCHS
    from repro.models import transformer as T
    cfg = ARCHS["smollm-360m"].reduced().scaled(
        n_layers=1, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab=128, head_dim=32, remat=False)
    return cfg, T.init_params(cfg, jax.random.PRNGKey(0))


def _prompts(n, seed=7, lo=4, hi=60):
    rng = np.random.default_rng(seed)
    return [tuple(int(x) for x in
                  rng.integers(1, 127, size=rng.integers(lo, hi)))
            for _ in range(n)]


def _run_live(model, reqs, fleet=None, n_units=1):
    cfg, params = model
    eng = ServingEngine(cfg, params, EngineConfig(
        n_units=n_units, fleet=fleet, elasticity=None, merging="none",
        pruning=None, result_cache=False, max_len=96,
        batch_buckets=(1, 2, 4),
        batching=StepBatchingConfig(max_batch=4, step_token_budget=16)))
    eng.cp.trace = []
    stats = eng.run([(float(i), r) for i, r in enumerate(reqs)])
    return eng, stats


class TestLiveHandoffTokenIdentity:
    def test_disaggregated_tokens_bitwise_equal_unified(self, tiny_model):
        """The §2.13 live acceptance gate: a prefill unit produces the
        boundary token, the KV blocks migrate between page arenas, and the
        decode unit finishes the sequence — greedy outputs bit-identical
        to the unified single-unit run."""
        prompts = _prompts(6)
        uni = [Request(prompt=p, n_new=4, deadline=1e9) for p in prompts]
        dis = [Request(prompt=p, n_new=4, deadline=1e9) for p in prompts]
        _, s0 = _run_live(tiny_model, uni)
        eng, s1 = _run_live(tiny_model, dis,
                            fleet=FleetSpec.parse("m0@prefill:1,m0@decode:1"))
        assert s0["completed"] == s1["completed"] == len(prompts)
        for a, b in zip(uni, dis):
            assert a.tokens == b.tokens
            assert len(b.tokens) == 4
        hand = [e for e in eng.cp.trace if e[0] == "handoff"]
        assert len(hand) == len(prompts)    # every sequence crossed planes
        # the real arena hand-over happened: src cache drained into dst
        phases = {m.phase: m.mid for m in eng.machines}
        src_c = eng.kvcaches[phases["prefill"]]
        dst_c = eng.kvcaches[phases["decode"]]
        assert src_c.stats["migrated_out"] > 0
        assert dst_c.stats["migrated_in"] == src_c.stats["migrated_out"]
        assert dst_c.stats["tokens_reused"] > 0   # migrated KV was attached
        # phase-weighted budgets (satellite): prefill 0.5x, decode 1.5x
        assert src_c.pool.n_blocks * 3 == dst_c.pool.n_blocks

    def test_handoff_telemetry_and_flow_arrows(self, tiny_model):
        prompts = _prompts(3, seed=5)
        reqs = [Request(prompt=p, n_new=3, deadline=1e9) for p in prompts]
        cfg, params = tiny_model
        eng = ServingEngine(cfg, params, EngineConfig(
            fleet=FleetSpec.parse("m0@prefill:1,m0@decode:1"),
            elasticity=None, merging="none", pruning=None,
            result_cache=False, max_len=96, batch_buckets=(1, 2),
            batching=StepBatchingConfig(max_batch=2, step_token_budget=16)))
        tel = Telemetry()
        eng.attach_telemetry(tel)
        eng.run([(float(i), r) for i, r in enumerate(reqs)])
        hand = tel.events_of("handoff")
        migs = tel.events_of("kv_migrate")
        assert hand and migs
        for ev in hand:
            assert {"task", "src", "dst", "cost"} <= set(ev)
        snap = tel.metrics.snapshot()
        assert snap["counters"]["handoffs"] == len(hand)
        assert snap["counters"]["kv_migrations"] >= len(migs)
        trace = chrome_trace(tel.events)
        validate_chrome_trace(trace)
        assert any(e["ph"] == "s" for e in trace["traceEvents"])
