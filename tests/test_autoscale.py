"""Autoscale subsystem coverage (DESIGN.md §2.7, no JAX models anywhere):

* the SCALER_POLICIES registry and its error path;
* exact decision-trace equivalence of the refactored ``queue`` policy
  against a verbatim replica of the pre-subsystem inline hysteresis, for
  both the simulator and the (stub-execution) serving engine;
* simulator <-> stub-engine decision equivalence with success-chance
  autoscaling *on* (the elasticity decisions themselves are
  substrate-independent);
* the success-chance signal (kernel path vs NumPy fallback agreement,
  depth-vs-urgency separation) and the cost-aware budget/Schmitt gates;
* machine-seconds accounting and Router plane-count autoscaling.
"""

import numpy as np
import pytest

from repro.core.fleet import FleetSpec
from repro.core.simulation import PETOracle, SimConfig, Simulator
from repro.core.tasks import Machine, PETMatrix, Task
from repro.serving.autoscale import (SCALER_POLICIES, ElasticityConfig,
                                     ScaleSignals, batch_chances,
                                     make_scaler_policy)
from repro.serving.autoscale.policies import CostAwareScaler
from repro.serving.cluster import (Router, make_engine_plane_factory,
                                   make_engine_planes)
from repro.serving.engine import EngineConfig, Request, ServingEngine


def _pet(seed=0, mean_range=(10, 20)):
    rng = np.random.default_rng(seed)
    return PETMatrix.generate(["generate"], ["m0"], rng,
                              mean_range=mean_range)


def _sim_tasks(n, seed=0, deadline=300.0, span=40.0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        t = float(rng.uniform(0, span))
        out.append(Task(ttype="generate", data_id=f"d{i}", op="generate",
                        params=(), arrival=t, deadline=t + deadline,
                        user=f"u{i % 4}"))
    return out


def _request_trace(n=40, seed=0, deadline=80.0, rate=0.5):
    rng = np.random.default_rng(seed)
    out, t = [], 0.0
    for i in range(n):
        out.append((t, Request(
            prompt=tuple(rng.integers(1, 1000, size=8).tolist()),
            op="generate", n_new=int(rng.integers(1, 4)),
            deadline=t + deadline)))
        t += float(rng.exponential(1.0 / rate))
    return out


def _mirror_tasks(trace):
    return [r.to_task(t, i) for i, (t, r) in enumerate(trace)]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

class TestRegistry:
    def test_all_policies_registered(self):
        assert {"queue", "success-chance", "cost-aware"} <= \
            set(SCALER_POLICIES)

    def test_unknown_policy_raises(self):
        with pytest.raises(KeyError, match="unknown scaler policy"):
            make_scaler_policy("nope", ElasticityConfig())

    def test_case_insensitive_like_heuristics(self):
        p = make_scaler_policy("QUEUE", ElasticityConfig())
        assert p.name == "queue"

    def test_bad_policy_surfaces_at_construction(self):
        with pytest.raises(KeyError):
            Simulator(_sim_tasks(2), [Machine(mid=0, mtype="m0")],
                      PETOracle(_pet()),
                      SimConfig(elasticity=ElasticityConfig(
                          policy="typo", max_extra=1)))


# ---------------------------------------------------------------------------
# queue policy == pre-subsystem inline hysteresis (decision traces)
# ---------------------------------------------------------------------------

class _LegacySim(Simulator):
    """Verbatim replica of the pre-subsystem Simulator.before_mapping."""

    LEGACY = dict(elastic_pool=3, scale_up_queue=6, scale_down_queue=1)

    def before_mapping(self, now):
        qlen = len(self.cp.batch)
        if (qlen >= self.LEGACY["scale_up_queue"]
                and len(self.machines)
                < self._base_pool + self.LEGACY["elastic_pool"]):
            proto = self.machines[0]
            self._extra_mid += 1
            self.machines.append(Machine(
                mid=self._extra_mid, mtype=proto.mtype, speed=proto.speed,
                queue_size=proto.queue_size, cost_rate=proto.cost_rate,
                power=proto.power))
            self.stats.scale_ups += 1
        elif (qlen <= self.LEGACY["scale_down_queue"]
              and len(self.machines) > self._base_pool):
            for i in range(len(self.machines) - 1, self._base_pool - 1, -1):
                m = self.machines[i]
                if m.running is None and not m.queue and m.busy_until <= now:
                    self.machines.pop(i)
                    self.stats.scale_downs += 1
                    break


class _LegacyEngine(ServingEngine):
    """Verbatim replica of the pre-subsystem engine before_mapping (queue
    hysteresis + 100-tick cooldown + count floor)."""

    LEGACY = dict(max_units=3, scale_up_queue=6, scale_down_queue=1)

    def before_mapping(self, now):
        if now < getattr(self, "_legacy_cooldown", 0.0):
            return
        qlen = len(self.batch)
        if qlen >= self.LEGACY["scale_up_queue"] and \
                len(self.units) < self.LEGACY["max_units"]:
            self._add_unit()
            self.stats["scale_ups"] += 1
            self._legacy_cooldown = now + 100.0
        elif qlen <= self.LEGACY["scale_down_queue"] and \
                len(self.units) > self.cfg.n_units:
            for i in range(len(self.units) - 1, -1, -1):
                m = self.units[i].machine
                if not m.queue and m.running is None and m.busy_until <= now:
                    self.units.pop(i)
                    self.stats["scale_downs"] += 1
                    self._legacy_cooldown = now + 100.0
                    break


class TestQueuePolicyLegacyEquivalence:
    def test_simulator_trace_identical_to_legacy_inline(self):
        pet = _pet(seed=2)
        kw = dict(heuristic="FCFS-RR", merging="none")
        tasks = _sim_tasks(60, seed=1, span=5.0, deadline=1e6)

        legacy = _LegacySim(
            [Task(**{f.name: getattr(t, f.name)
                     for f in t.__dataclass_fields__.values()
                     if f.name in ("ttype", "data_id", "op", "params",
                                   "arrival", "deadline", "user")})
             for t in tasks],
            [Machine(mid=0, mtype="m0", queue_size=2)],
            PETOracle(pet, seed=3), SimConfig(**kw))
        legacy.cp.trace = []
        lst = legacy.run()

        new = Simulator(
            _sim_tasks(60, seed=1, span=5.0, deadline=1e6),
            [Machine(mid=0, mtype="m0", queue_size=2)],
            PETOracle(pet, seed=3),
            SimConfig(elasticity=ElasticityConfig(
                policy="queue", max_extra=3, scale_up_queue=6,
                scale_down_queue=1), **kw))
        new.cp.trace = []
        nst = new.run()

        assert lst.scale_ups > 0 and lst.scale_downs > 0  # non-vacuous
        assert new.cp.trace == legacy.cp.trace
        assert (nst.scale_ups, nst.scale_downs) == \
            (lst.scale_ups, lst.scale_downs)
        assert (nst.on_time, nst.missed, nst.dropped) == \
            (lst.on_time, lst.missed, lst.dropped)

    def test_engine_trace_identical_to_legacy_inline(self):
        pet = _pet(seed=2)
        trace = _request_trace(n=50, seed=4, deadline=200.0, rate=1.5)
        kw = dict(heuristic="EDF", merging="none", result_cache=False,
                  prefix_cache=False, n_units=1)

        legacy = _LegacyEngine(None, None, EngineConfig(elasticity=None, **kw),
                               stub_oracle=PETOracle(pet, seed=9))
        legacy.cp.trace = []
        lst = legacy.run(trace)

        new = ServingEngine(None, None, EngineConfig(
            elasticity=ElasticityConfig(policy="queue", max_extra=2,
                                        scale_up_queue=6, scale_down_queue=1,
                                        cooldown=100.0), **kw),
            stub_oracle=PETOracle(pet, seed=9))
        new.cp.trace = []
        nst = new.run(trace)

        assert lst["scale_ups"] > 0                        # non-vacuous
        assert new.cp.trace == legacy.cp.trace
        assert (nst["scale_ups"], nst["scale_downs"]) == \
            (lst["scale_ups"], lst["scale_downs"])
        assert (nst["on_time"], nst["missed"], nst["dropped"]) == \
            (lst["on_time"], lst["missed"], lst["dropped"])

    def test_disabled_matches_fixed_pool(self):
        """elasticity=None and max_extra=0 both mean: no scaler, identical
        decisions to a fixed pool."""
        pet = _pet(seed=5)
        trace = _request_trace(n=30, seed=2)
        traces = []
        for elasticity in (None, ElasticityConfig(max_extra=0)):
            eng = ServingEngine(None, None, EngineConfig(
                n_units=2, heuristic="EDF", merging="none",
                result_cache=False, prefix_cache=False,
                elasticity=elasticity), stub_oracle=PETOracle(pet, seed=1))
            assert eng.scaler is None
            eng.cp.trace = []
            eng.run(trace)
            traces.append(eng.cp.trace)
        assert traces[0] == traces[1]


# ---------------------------------------------------------------------------
# cross-substrate equivalence with autoscaling ON
# ---------------------------------------------------------------------------

class TestCrossSubstrateEquivalence:
    # the legacy ``queue`` hysteresis is deliberately NOT here: its engine
    # and simulator shrink semantics differed pre-subsystem (scan-all vs
    # extras-only victim choice) and are preserved verbatim per substrate
    # (see TestQueuePolicyLegacyEquivalence), so cross-substrate trace
    # equality — which pre-PR was only ever asserted with elasticity off —
    # holds for the new policies, whose adapters share one implementation
    # per substrate by construction of this subsystem.
    @pytest.mark.parametrize("policy", ["success-chance", "cost-aware"])
    def test_sim_and_stub_engine_scale_identically(self, policy):
        pet = _pet(seed=3, mean_range=(8, 16))
        trace = _request_trace(n=40, seed=1, deadline=60.0, rate=1.0)
        el = ElasticityConfig(policy=policy, max_extra=2, scale_up_queue=6,
                              scale_down_queue=1, low_chance=0.6)

        eng = ServingEngine(None, None, EngineConfig(
            n_units=1, heuristic="EDF", merging="none", result_cache=False,
            prefix_cache=False, elasticity=el),
            stub_oracle=PETOracle(pet, seed=11))
        eng.cp.trace = []
        stats = eng.run(trace)

        sim = Simulator(
            _mirror_tasks(trace),
            FleetSpec.homogeneous(1),   # the stub engine's machines exactly
            PETOracle(pet, seed=11),
            SimConfig(heuristic="EDF", merging="none", elasticity=el))
        sim.cp.trace = []
        st = sim.run()

        assert stats["scale_ups"] > 0                      # non-vacuous
        assert sim.cp.trace == eng.cp.trace
        assert (st.scale_ups, st.scale_downs) == \
            (stats["scale_ups"], stats["scale_downs"])
        assert (st.on_time, st.missed, st.dropped) == \
            (stats["on_time"], stats["missed"], stats["dropped"])
        assert st.machine_seconds == pytest.approx(stats["machine_seconds"])


# ---------------------------------------------------------------------------
# the success-chance signal
# ---------------------------------------------------------------------------

class TestSignals:
    def test_kernel_and_numpy_paths_agree(self):
        pet = _pet(seed=7)
        oracle = PETOracle(pet, seed=0)
        machines = [Machine(mid=0, mtype="m0", queue_size=4)]
        batch = [Task(ttype="generate", data_id=f"d{i}", op="generate",
                      arrival=0.0, deadline=30.0 + 15.0 * i)
                 for i in range(6)]
        kernel = batch_chances(batch, machines, oracle, 0.0, use_kernel=True)
        numpy_ = batch_chances(batch, machines, oracle, 0.0, use_kernel=False)
        assert kernel.shape == numpy_.shape == (6,)
        np.testing.assert_allclose(kernel, numpy_, atol=1e-5)

    def test_depth_alone_does_not_degrade_loose_deadlines(self):
        """A deep queue of slack-deadline work keeps a high aggregate
        chance; the same queue with tight deadlines collapses it — the
        separation queue-depth scaling cannot express."""
        pet = _pet(seed=7)
        oracle = PETOracle(pet, seed=0)
        machines = [Machine(mid=0, mtype="m0", queue_size=4)]
        loose = [Task(ttype="generate", data_id=f"l{i}", op="generate",
                      arrival=0.0, deadline=5000.0) for i in range(12)]
        tight = [Task(ttype="generate", data_id=f"t{i}", op="generate",
                      arrival=0.0, deadline=25.0) for i in range(12)]
        c_loose = batch_chances(loose, machines, oracle, 0.0).mean()
        c_tight = batch_chances(tight, machines, oracle, 0.0).mean()
        assert c_loose > 0.95
        assert c_tight < 0.4

    def test_infinite_deadlines_score_one(self):
        oracle = PETOracle(_pet(), seed=0)
        machines = [Machine(mid=0, mtype="m0")]
        batch = [Task(ttype="generate", data_id="x", op="generate")]
        assert batch_chances(batch, machines, oracle, 0.0).tolist() == [1.0]

    def test_empty_batch_signal(self):
        sig = ScaleSignals(0.0, 0)
        assert sig.chance() == 1.0
        assert sig.at_risk(0.5) == 0

    def test_signal_caps_scored_tasks(self):
        oracle = PETOracle(_pet(), seed=0)
        machines = [Machine(mid=0, mtype="m0")]
        batch = [Task(ttype="generate", data_id=f"d{i}", op="generate",
                      deadline=100.0) for i in range(40)]
        out = batch_chances(batch, machines, oracle, 0.0, signal_tasks=8)
        assert out.shape == (8,)


# ---------------------------------------------------------------------------
# cost-aware gates
# ---------------------------------------------------------------------------

class TestCostAware:
    def _sig(self, qlen, at_risk, extra_ms):
        chances = np.concatenate([np.zeros(at_risk),
                                  np.ones(max(qlen - at_risk, 0))])
        return ScaleSignals(0.0, qlen, chances_fn=lambda: chances,
                            extra_machine_seconds=extra_ms)

    def test_budget_gates_scale_up(self):
        cfg = ElasticityConfig(policy="cost-aware",
                               budget_machine_seconds=100.0,
                               pressure_lam=1.0, pressure_on=1.0)
        pol = CostAwareScaler(cfg)
        assert pol.decide(self._sig(8, 8, 0.0)) == 1      # in budget
        assert pol.decide(self._sig(8, 8, 100.0)) == -1   # burned: drain

    def test_zero_budget_never_scales_up(self):
        cfg = ElasticityConfig(policy="cost-aware",
                               budget_machine_seconds=0.0,
                               pressure_lam=1.0, pressure_on=1.0)
        pol = CostAwareScaler(cfg)
        assert all(pol.decide(self._sig(10, 10, 0.0)) == -1
                   for _ in range(5))

    def test_schmitt_trigger_does_not_chatter(self):
        """At-risk counts oscillating across the on-level (above the 20%-
        separated off-level) must hold one engaged stretch, not flap."""
        cfg = ElasticityConfig(policy="cost-aware", pressure_lam=0.5,
                               pressure_on=2.0, scale_down_queue=0)
        pol = CostAwareScaler(cfg)
        decisions = [pol.decide(self._sig(6, r, 0.0))
                     for r in (3, 3, 1, 3, 1, 3, 1)]
        # engages on the second observation and never releases mid-noise
        assert decisions[0] == 0
        assert all(d == 1 for d in decisions[1:])

    def test_budget_respected_end_to_end(self):
        pet = _pet(seed=3, mean_range=(8, 16))
        trace = _request_trace(n=60, seed=1, deadline=40.0, rate=2.0)
        eng = ServingEngine(None, None, EngineConfig(
            n_units=1, heuristic="EDF", merging="none", result_cache=False,
            prefix_cache=False,
            elasticity=ElasticityConfig(policy="cost-aware", max_extra=3,
                                        budget_machine_seconds=150.0,
                                        low_chance=0.6)),
            stub_oracle=PETOracle(pet, seed=11))
        stats = eng.run(trace)
        assert stats["scale_ups"] > 0
        # one in-flight extra can overshoot by at most its own residency
        # since the last decision; the budget is enforced at decisions
        assert stats["extra_machine_seconds"] <= 150.0 + 3 * 60.0


# ---------------------------------------------------------------------------
# accounting
# ---------------------------------------------------------------------------

class TestAccounting:
    def test_machine_seconds_is_pool_integral(self):
        """With scaling enabled but never triggered, machine-seconds must
        equal base_pool x makespan exactly."""
        pet = _pet(seed=5)
        sim = Simulator(
            _sim_tasks(20, seed=2, deadline=1e6),
            [Machine(mid=0, mtype="m0"), Machine(mid=1, mtype="m0")],
            PETOracle(pet, seed=1),
            SimConfig(elasticity=ElasticityConfig(
                policy="queue", max_extra=2, scale_up_queue=10 ** 9,
                scale_down_queue=-1)))
        st = sim.run()
        assert st.scale_ups == 0 and st.scale_downs == 0
        assert st.machine_seconds == pytest.approx(2.0 * st.makespan)
        assert st.extra_machine_seconds == 0.0

    def test_fixed_pool_still_reports_machine_seconds(self):
        """Scaling disabled is not zero cost: the integral degenerates to
        pool x makespan (consumers need no special case)."""
        pet = _pet(seed=5)
        sim = Simulator(_sim_tasks(10, seed=2, deadline=1e6),
                        [Machine(mid=0, mtype="m0")],
                        PETOracle(pet, seed=1), SimConfig())
        st = sim.run()
        assert st.machine_seconds == pytest.approx(st.makespan)
        eng = ServingEngine(None, None, EngineConfig(
            n_units=2, elasticity=None, heuristic="EDF", merging="none",
            result_cache=False, prefix_cache=False),
            stub_oracle=PETOracle(pet, seed=1))
        stats = eng.run(_request_trace(n=8, seed=0))
        assert stats["machine_seconds"] == \
            pytest.approx(2.0 * eng.cp.stats["last_completion"])

    def test_scaled_run_accounts_extras(self):
        pet = _pet(seed=5)
        sim = Simulator(
            _sim_tasks(60, seed=1, span=5.0, deadline=1e6),
            [Machine(mid=0, mtype="m0", queue_size=2)],
            PETOracle(pet, seed=3),
            SimConfig(elasticity=ElasticityConfig(
                policy="queue", max_extra=3, scale_up_queue=6,
                scale_down_queue=1)))
        st = sim.run()
        assert st.scale_ups > 0
        assert 0.0 < st.extra_machine_seconds < st.machine_seconds
        assert st.machine_seconds > st.makespan          # >1 unit at times
        assert st.scale_decisions > 0


# ---------------------------------------------------------------------------
# Router plane-count autoscaling
# ---------------------------------------------------------------------------

def _stub_plane_router(pet, policy="success-chance", max_extra=3,
                       cooldown=30.0, **el_kw):
    ecfg = EngineConfig(n_units=1, elasticity=None, result_cache=False,
                        prefix_cache=False, heuristic="EDF", merging="none")
    planes = make_engine_planes(None, None, ecfg, 1,
                                stub_oracles=[PETOracle(pet, seed=11)])
    factory = make_engine_plane_factory(
        None, None, ecfg,
        stub_oracle_fn=lambda pid: PETOracle(pet, seed=11 + pid))
    return Router(planes, policy="least-loaded",
                  autoscale=ElasticityConfig(policy=policy,
                                             max_extra=max_extra,
                                             cooldown=cooldown, **el_kw),
                  plane_factory=factory)


class TestPlaneAutoscale:
    def test_requires_factory(self):
        with pytest.raises(ValueError, match="plane_factory"):
            Router([Simulator([], [Machine(mid=0, mtype="m0")],
                              PETOracle(_pet()))],
                   autoscale=ElasticityConfig(max_extra=1))

    def test_sustained_overload_adds_and_retires_planes(self):
        pet = _pet(seed=3)
        router = _stub_plane_router(pet, low_chance=0.5)
        t, rng = 0.0, np.random.default_rng(9)
        for i in range(80):
            router.submit(Request(prompt=(i, 2, 3), op="generate", n_new=2,
                                  deadline=t + 80.0), t)
            t += float(rng.exponential(4.0))
        stats = router.drain()
        auto = stats["router"]["autoscale"]
        assert auto["plane_scale_ups"] > 0
        assert auto["plane_scale_downs"] > 0
        assert len(router.retired) == auto["plane_scale_downs"]
        # retired planes' work still aggregates: nothing vanishes
        assert stats["n_requests"] == 80
        assert stats["on_time"] + stats["missed"] + stats["dropped"] == 80
        assert sum(stats["router"]["routed"].values()) == 80
        assert len(stats["router"]["routed"]) == \
            1 + auto["plane_scale_ups"]
        assert auto["plane_seconds"] > 0.0

    def test_base_planes_never_retired(self):
        pet = _pet(seed=3)
        router = _stub_plane_router(pet, policy="queue", max_extra=2,
                                    scale_up_queue=4, scale_down_queue=10 ** 6)
        # scale_down_queue huge: the policy always votes -1 when idle, so
        # shrink pressure is constant — yet base planes must survive
        for i in range(30):
            router.submit(Request(prompt=(i,), op="generate", n_new=1,
                                  deadline=1e9), i * 50.0)
        router.drain()
        assert {p.pid for p in router.planes} >= {0}
        assert all(p.pid != 0 for p in router.retired)

    def test_new_planes_visible_to_routing_and_lookup(self):
        pet = _pet(seed=3)
        router = _stub_plane_router(pet, low_chance=0.5)
        t, rng = 0.0, np.random.default_rng(9)
        for i in range(60):
            router.submit(Request(prompt=(i, 2, 3), op="generate", n_new=2,
                                  deadline=t + 80.0), t)
            t += float(rng.exponential(4.0))
        assert len(router.planes) > 1                 # grew mid-stream
        # the shared view tracks the live plane list object
        assert router.shared.planes is router.planes
        routed_new = sum(n for pid, n in router.stats["routed"].items()
                         if pid not in router._base_pids)
        assert routed_new > 0
        router.drain()
